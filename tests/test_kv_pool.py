"""Paged KV pool allocator: deterministic unit tests plus a randomized
property test (hypothesis when available, a seeded fallback sweep
otherwise) driving alloc / append / fork / free sequences with
``check_invariants()`` after every operation — refcounts match live
tables, no page is ever double-freed, nothing leaks, commitments never
exceed the free list.
"""
import numpy as np
import pytest

from repro.serving.kv_pool import KVPagePool, PoolExhausted

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # container has no hypothesis:
    HAVE_HYPOTHESIS = False             # fall back to a seeded sweep


def _prompt(rng, n):
    return rng.integers(0, 97, n).astype(np.int32)


# ---------------------------------------------------------------------------
# deterministic unit tests
# ---------------------------------------------------------------------------

def test_alloc_free_roundtrip():
    pool = KVPagePool(num_pages=8, page_size=4)
    t, shared = pool.alloc_prompt(np.arange(10, dtype=np.int32), 10)
    assert shared == 0
    assert len(t.pages) == 3 and t.length == 10 and t.last_page_len == 2
    assert pool.pages_in_use == 3
    pool.check_invariants()
    pool.free(t)
    assert pool.pages_in_use == 0 and not t.alive
    pool.check_invariants()


def test_double_free_raises():
    pool = KVPagePool(num_pages=4, page_size=2)
    t, _ = pool.alloc_prompt(np.arange(3, dtype=np.int32), 3)
    pool.free(t)
    with pytest.raises(RuntimeError, match="already freed"):
        pool.free(t)
    pool.check_invariants()


def test_append_within_and_beyond_budget():
    """total_tokens commits exactly the decode budget: appends inside it
    always succeed (boundary growth draws committed pages), the first
    append past it raises without corrupting the pool."""
    pool = KVPagePool(num_pages=8, page_size=4)
    t, _ = pool.alloc_prompt(np.arange(6, dtype=np.int32), 12)
    assert t.budget == 1                       # pages_for(12) - pages_for(6)
    for _ in range(6):                         # 6 -> 12 tokens
        plan = pool.prepare_append(t)
        assert plan.slot == t.length % 4
        pool.commit_append(t)
        pool.check_invariants()
    assert t.length == 12 and len(t.pages) == 3 and t.budget == 0
    with pytest.raises(PoolExhausted, match="budget"):
        pool.prepare_append(t)
    pool.check_invariants()
    pool.free(t)


def test_prepare_append_is_idempotent():
    """A crashed step may retry prepare_append before committing: the
    replan must return the same placement without drawing a second
    page."""
    pool = KVPagePool(num_pages=8, page_size=4)
    t, _ = pool.alloc_prompt(np.arange(4, dtype=np.int32), 12)
    p1 = pool.prepare_append(t)                # boundary: grows a page
    in_use = pool.pages_in_use
    p2 = pool.prepare_append(t)                # retry before commit
    assert (p1.page, p1.slot) == (p2.page, p2.slot)
    assert p2.cow_src is None
    assert pool.pages_in_use == in_use
    pool.commit_append(t)
    pool.check_invariants()
    pool.free(t)


def test_prefix_sharing_and_epoch_invalidation():
    pool = KVPagePool(num_pages=8, page_size=4)
    rng = np.random.default_rng(0)
    prompt = _prompt(rng, 8)                   # two full pages
    t1, s1 = pool.alloc_prompt(prompt, 8)
    assert s1 == 0
    pool.register(prompt, t1)
    t2, s2 = pool.alloc_prompt(prompt, 8)      # full prefix hit
    assert s2 == 8 and t2.pages == t1.pages
    assert pool.pages_in_use == 2              # shared, not duplicated
    assert pool.prefix_hits == 1 and pool.prefix_tokens_shared == 8
    pool.check_invariants()
    # a longer prompt adopts the longest indexed full-page prefix
    longer = np.concatenate([prompt, _prompt(rng, 6)])
    t3, s3 = pool.alloc_prompt(longer, 14)
    assert s3 == 8 and t3.pages[:2] == t1.pages and len(t3.pages) == 4
    pool.check_invariants()
    for t in (t3, t2, t1):
        pool.free(t)
    assert pool.pages_in_use == 0
    # the pages recycled: their epoch bump must invalidate the index
    t4, s4 = pool.alloc_prompt(prompt, 8)
    assert s4 == 0, "stale prefix entry survived page recycling"
    pool.check_invariants()
    pool.free(t4)


def test_can_admit_tracks_commitments():
    """Admission capacity is free pages net of committed decode budgets —
    a second request must be refused while the first's committed pages
    would not fit, and clear after the first frees."""
    pool = KVPagePool(num_pages=6, page_size=4)
    p1, p2 = np.arange(4, dtype=np.int32), np.arange(50, 54, dtype=np.int32)
    assert pool.can_admit(p1, 16)              # 4 pages
    t1, _ = pool.alloc_prompt(p1, 16)
    assert pool.pages_in_use == 1 and pool.available == 2
    assert pool.can_admit(p2, 8)               # 2 pages: fits
    assert not pool.can_admit(p2, 12)          # 3 pages: over-commits
    with pytest.raises(PoolExhausted, match="available"):
        pool.alloc_prompt(p2, 12)
    pool.check_invariants()
    pool.free(t1)
    assert pool.can_admit(p2, 12)
    pool.check_invariants()


def test_fork_copy_on_write():
    """fork shares every page with zero copies; the first append on
    either side copy-on-writes the shared partial last page, after which
    both sides append in place."""
    pool = KVPagePool(num_pages=8, page_size=4)
    t, _ = pool.alloc_prompt(np.arange(6, dtype=np.int32), 10)
    child = pool.fork(t, 10)
    assert child.pages == t.pages and pool.pages_in_use == 2
    pool.check_invariants()
    plan = pool.prepare_append(t)              # shared partial page: CoW
    assert plan.cow_src == child.pages[-1] and plan.page != plan.cow_src
    assert plan.slot == 2
    pool.commit_append(t)
    assert pool.cow_forks == 1
    pool.check_invariants()
    plan2 = pool.prepare_append(child)         # child's page now exclusive
    assert plan2.cow_src is None and plan2.page == child.pages[-1]
    pool.commit_append(child)
    pool.check_invariants()
    pool.free(t)
    pool.free(child)
    assert pool.pages_in_use == 0
    pool.check_invariants()


def test_fork_reserves_cow_pages_or_refuses():
    """A fork at a partial page needs the CoW reserve on BOTH sides; a
    pool that cannot commit it must refuse rather than deadlock a side
    mid-decode."""
    pool = KVPagePool(num_pages=3, page_size=4)
    t, _ = pool.alloc_prompt(np.arange(6, dtype=np.int32), 6)
    with pytest.raises(PoolExhausted, match="fork"):
        pool.fork(t, 6)                        # needs 2 reserves, has 1
    pool.check_invariants()
    pool.free(t)


def test_page_table_arrays_csr():
    pool = KVPagePool(num_pages=8, page_size=4)
    a, _ = pool.alloc_prompt(np.arange(6, dtype=np.int32), 6)
    b, _ = pool.alloc_prompt(np.arange(9, dtype=np.int32), 9)
    indptr, indices, lastlen = pool.page_table_arrays([a, b])
    np.testing.assert_array_equal(indptr, [0, 2, 5])
    np.testing.assert_array_equal(indices, a.pages + b.pages)
    np.testing.assert_array_equal(lastlen, [2, 1])
    pool.free(a), pool.free(b)


def test_constructor_validation():
    with pytest.raises(ValueError, match="pool needs"):
        KVPagePool(0, 4)
    with pytest.raises(ValueError, match="pool needs"):
        KVPagePool(4, 0)
    pool = KVPagePool(2, 2)
    with pytest.raises(ValueError, match="at least one token"):
        pool.alloc_prompt(np.zeros(0, np.int32), 4)
    with pytest.raises(ValueError, match="total_tokens"):
        pool.alloc_prompt(np.zeros(3, np.int32), 2)


# ---------------------------------------------------------------------------
# eviction-aware prefix retention
# ---------------------------------------------------------------------------

def test_retention_parks_and_revives_prefix_pages():
    """With ``prefix_keep_pages`` on, freeing a registered table parks
    its zero-ref full-prefix pages in the retention LRU (not in use, not
    free) and a same-prompt re-admission adopts them as a prefix hit."""
    pool = KVPagePool(num_pages=8, page_size=4, prefix_keep_pages=4)
    prompt = _prompt(np.random.default_rng(0), 10)    # 2 full pages + tail
    t, shared = pool.alloc_prompt(prompt, 12)
    assert shared == 0
    head = list(t.pages[:2])
    pool.register(prompt, t)
    pool.free(t)
    assert pool.prefix_pages_retained == 2            # full pages only
    assert pool.pages_in_use == 0                     # retained != in use
    pool.check_invariants()

    t2, shared2 = pool.alloc_prompt(prompt, 12)
    assert shared2 == 8 and pool.prefix_hits == 1
    assert pool.prefix_pages_retained == 0            # revived from the LRU
    assert list(t2.pages[:2]) == head                 # the SAME pages
    pool.check_invariants()
    pool.free(t2)
    assert pool.prefix_pages_retained == 2            # parked again


def test_retention_lru_bound_evicts_oldest_retirement():
    """The LRU never exceeds its bound: when a later retirement pushes
    it over, the oldest-retired pages evict (epoch bump invalidates
    their index entries) and only the newest prefix stays adoptable."""
    rng = np.random.default_rng(1)
    pool = KVPagePool(num_pages=16, page_size=2, prefix_keep_pages=2)
    a, b = _prompt(rng, 4), _prompt(rng, 4)
    for p in (a, b):
        t, _ = pool.alloc_prompt(p, 4)
        pool.register(p, t)
        pool.free(t)
        pool.check_invariants()
    assert pool.prefix_pages_retained == 2            # bound held

    tb, shared_b = pool.alloc_prompt(b, 4)            # newest: still hot
    assert shared_b == 4
    ta, shared_a = pool.alloc_prompt(a, 4)            # oldest: evicted
    assert shared_a == 0
    pool.free(ta), pool.free(tb)
    pool.check_invariants()


def test_retention_trim_preserves_shortest_prefix():
    """Within one retirement, pages deepest in the prompt retire as the
    coldest — a trimmed LRU keeps page 0, so the shortest (most
    reusable) full-page prefix survives and still matches."""
    pool = KVPagePool(num_pages=8, page_size=2, prefix_keep_pages=1)
    prompt = _prompt(np.random.default_rng(2), 4)     # 2 full pages
    t, _ = pool.alloc_prompt(prompt, 4)
    first_page = t.pages[0]
    pool.register(prompt, t)
    pool.free(t)
    assert pool.prefix_pages_retained == 1
    t2, shared = pool.alloc_prompt(prompt, 6)
    assert shared == 2                                # one-page prefix hit
    assert t2.pages[0] == first_page
    pool.check_invariants()
    pool.free(t2)


def test_retained_pages_reclaimed_under_pressure():
    """Retention never causes exhaustion: retained pages count as
    ``available`` and a large admission reclaims them (oldest first)
    instead of raising PoolExhausted."""
    rng = np.random.default_rng(3)
    pool = KVPagePool(num_pages=4, page_size=2, prefix_keep_pages=4)
    a = _prompt(rng, 4)
    t, _ = pool.alloc_prompt(a, 4)
    pool.register(a, t)
    pool.free(t)
    assert pool.prefix_pages_retained == 2 and pool.available == 4

    big = _prompt(rng, 8)                             # needs all 4 pages
    assert pool.can_admit(big, 8)
    tb, _ = pool.alloc_prompt(big, 8)
    assert pool.pages_in_use == 4 and pool.prefix_pages_retained == 0
    pool.check_invariants()
    pool.free(tb)
    ta, shared = pool.alloc_prompt(a, 4)              # epochs bumped:
    assert shared == 0                                # stale entry dropped
    pool.check_invariants()
    pool.free(ta)


def test_retention_constructor_validation():
    with pytest.raises(ValueError, match="prefix_keep_pages"):
        KVPagePool(4, 2, prefix_keep_pages=-1)
    assert KVPagePool(4, 2, prefix_keep_pages=0).prefix_pages_retained == 0


# ---------------------------------------------------------------------------
# randomized property test
# ---------------------------------------------------------------------------

def _drive(seed: int, steps: int = 120) -> None:
    """Random alloc/register/append/fork/free sequence; the pool's
    invariants must hold after EVERY operation, exhaustion must raise the
    typed error exactly when predicted, and freeing the survivors must
    return every page."""
    rng = np.random.default_rng(seed)
    ps = int(rng.integers(1, 6))
    pool = KVPagePool(num_pages=int(rng.integers(4, 24)), page_size=ps)
    live = []
    for _ in range(steps):
        op = int(rng.integers(0, 4))
        if op == 0:                                      # admit
            plen = int(rng.integers(1, 4 * ps + 1))
            total = plen + int(rng.integers(0, 2 * ps + 1))
            prompt = _prompt(rng, plen)
            if pool.can_admit(prompt, total):
                t, _ = pool.alloc_prompt(prompt, total)
                live.append(t)
                if rng.integers(0, 2):
                    pool.register(prompt, t)
            else:
                with pytest.raises(PoolExhausted):
                    pool.alloc_prompt(prompt, total)
        elif op == 1 and live:                           # append one token
            t = live[int(rng.integers(len(live)))]
            needs_page = len(t.pages) < t.length // ps + 1 \
                or pool._ref[t.pages[-1]] > 1
            if needs_page and t.budget < 1:
                with pytest.raises(PoolExhausted):
                    pool.prepare_append(t)
            else:
                plan = pool.prepare_append(t)
                assert 0 <= plan.page < pool.num_pages
                assert plan.slot == t.length % ps
                pool.commit_append(t)
        elif op == 2 and live:                           # fork
            t = live[int(rng.integers(len(live)))]
            total = t.length + int(rng.integers(0, 2 * ps + 1))
            reserve = 1 if t.length % ps else 0
            need = pool.pages_for(total) - pool.pages_for(t.length) \
                + 2 * reserve
            if need <= pool.available:
                live.append(pool.fork(t, total))
            else:
                with pytest.raises(PoolExhausted):
                    pool.fork(t, total)
        elif op == 3 and live:                           # free (+ double)
            t = live.pop(int(rng.integers(len(live))))
            pool.free(t)
            with pytest.raises(RuntimeError):
                pool.free(t)
        pool.check_invariants()
    for t in live:
        pool.free(t)
        pool.check_invariants()
    assert pool.pages_in_use == 0, "pages leaked after freeing every table"
    assert pool.available == len(pool._free) == pool.num_pages


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**32 - 1))
    def test_pool_random_ops(seed):
        _drive(seed)
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_pool_random_ops(seed):
        _drive(seed)
