"""Per-arch smoke tests: reduced same-family config, one train loss +
prefill + decode step on CPU, asserting shapes and finiteness.
(Deliverable f: every assigned architecture as a selectable config.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.config import get_config, list_archs, reduced
from repro.models import decode_step, init_params, loss_fn, prefill

ARCHS = list_archs()
B, S = 2, 128


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.fold_in(key, 1),
                                          (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, S, cfg.frontend_embed_dim), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, 16, cfg.frontend_embed_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)

    loss, parts = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch

    logits, state = jax.jit(lambda p, b: prefill(p, b, cfg))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    dbatch = {k: v for k, v in batch.items() if k != "labels"}
    dbatch["tokens"] = batch["tokens"][:, :1]
    logits2, state2 = jax.jit(
        lambda p, s, b: decode_step(p, s, b, cfg))(params, state, dbatch)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(state2["pos"]) == int(state["pos"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_published(arch):
    """Analytic param counts land near the published model sizes."""
    published = {
        "gemma3-4b": 3.9e9, "smollm-360m": 0.36e9, "qwen2-72b": 72.7e9,
        "mistral-nemo-12b": 12.2e9, "qwen3-moe-30b-a3b": 30.5e9,
        "llama4-maverick-400b-a17b": 400e9, "seamless-m4t-large-v2": 2.0e9,
        "jamba-v0.1-52b": 52e9, "qwen2-vl-7b": 7.6e9, "mamba2-370m": 0.37e9,
        "mixtral-8x7b": 46.7e9, "phi35-moe": 41.9e9,
    }
    got = get_config(arch).param_count()
    want = published[arch]
    assert abs(got - want) / want < 0.08, (arch, got, want)
