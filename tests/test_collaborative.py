"""Two-tier collaborative MoE execution: correctness + async-schedulability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig
from repro.core import collaborative as collab
from repro.core.cache import init_cache_state


def _tiers(key, L=3, E=4, D=16, F=32, ccfg=None, policy="lru"):
    ks = jax.random.split(key, 3)
    ccfg = ccfg or CacheConfig(num_indexes=2, num_ways=2, policy=policy)
    w1 = jax.random.normal(ks[0], (L, E, D, F), jnp.float32) * 0.1
    w3 = jax.random.normal(ks[1], (L, E, D, F), jnp.float32) * 0.1
    w2 = jax.random.normal(ks[2], (L, E, F, D), jnp.float32) * 0.1
    return collab.init_tiers(w1, w3, w2, ccfg, num_experts=E,
                             key=jax.random.PRNGKey(7)), ccfg


def _dense_ref(tiers, layer, x, top_i, top_w):
    """Reference: plain MoE with the host-tier weights."""
    T, K = top_i.shape
    y = np.zeros_like(np.asarray(x))
    for t in range(T):
        for k in range(K):
            e = int(top_i[t, k])
            w1 = np.asarray(tiers.host_w1[layer, e])
            w3 = np.asarray(tiers.host_w3[layer, e])
            w2 = np.asarray(tiers.host_w2[layer, e])
            xt = np.asarray(x[t])
            h = (xt @ w1) / (1 + np.exp(-(xt @ w1))) * (xt @ w3)
            y[t] += float(top_w[t, k]) * (h @ w2)
    return y


@pytest.mark.parametrize("policy", ["lru", "fifo", "random"])
def test_collaborative_output_matches_dense_reference(policy):
    """Hit path, miss path, and mixed must all produce the SAME math as a
    plain MoE layer — the tiers change where weights are read, never the
    result (the paper's no-accuracy-tradeoff claim)."""
    key = jax.random.PRNGKey(0)
    tiers, ccfg = _tiers(key, policy=policy)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    top_i = jnp.asarray([[0, 1], [2, 3]])
    top_w = jnp.asarray([[0.6, 0.4], [0.5, 0.5]], jnp.float32)
    for layer in (0, 1, 2):   # covered cold, covered, beyond coverage
        for rep in range(3):  # cold -> warm transitions
            y, tiers, stats = collab.collaborative_moe(
                tiers, jnp.int32(layer), x, top_i, top_w, ccfg)
            ref = _dense_ref(tiers, layer, x, top_i, top_w)
            np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4,
                                       atol=2e-4)


def test_post_fetch_populates_cache_for_next_step():
    key = jax.random.PRNGKey(1)
    tiers, ccfg = _tiers(key)
    x = jax.random.normal(key, (1, 16), jnp.float32)
    ti = jnp.asarray([[0, 1]])
    tw = jnp.asarray([[0.5, 0.5]], jnp.float32)
    _, tiers, s0 = collab.collaborative_moe(tiers, jnp.int32(0), x, ti, tw, ccfg)
    assert int(s0["hits"]) == 0 and int(s0["fetched_experts"]) == 2
    _, tiers, s1 = collab.collaborative_moe(tiers, jnp.int32(0), x, ti, tw, ccfg)
    assert int(s1["hits"]) == 2 and int(s1["fetched_experts"]) == 0
    # slot buffer now holds the actual expert weights
    tags = np.asarray(tiers.state.tags[0])
    for way, e in enumerate(tags):
        if e >= 0:
            np.testing.assert_array_equal(
                np.asarray(tiers.slot_w1[0 * ccfg.num_ways + way]),
                np.asarray(tiers.host_w1[0, e]))


def test_post_fetch_is_async_schedulable():
    """The paper's dual-copy-engine overlap maps to XLA scheduling freedom:
    the layer output must NOT data-depend on the slot-buffer update. We
    check this structurally: with the new slot buffers replaced by zeros,
    the output y is unchanged."""
    key = jax.random.PRNGKey(2)
    tiers, ccfg = _tiers(key)
    x = jax.random.normal(key, (1, 16), jnp.float32)
    ti = jnp.asarray([[0, 1]])
    tw = jnp.asarray([[0.5, 0.5]], jnp.float32)
    y1, t1, _ = collab.collaborative_moe(tiers, jnp.int32(0), x, ti, tw, ccfg)
    zeroed = tiers._replace(slot_w1=jnp.zeros_like(tiers.slot_w1),
                            slot_w3=jnp.zeros_like(tiers.slot_w3),
                            slot_w2=jnp.zeros_like(tiers.slot_w2))
    y2, _, _ = collab.collaborative_moe(zeroed, jnp.int32(0), x, ti, tw, ccfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))


def test_offloaded_path_matches_default():
    """The host-space + compute_on("device_host") variant — the literal
    memory-space form of the paper's workflow — computes identically to
    the default path, across hit/miss/post-fetch transitions. Backends
    without pinned_host fall back to unpinned_host (this CPU container);
    backends with no host space at all skip."""
    if not collab.host_offload_supported():
        pytest.skip("backend exposes no host memory space")
    host_kind, _ = collab.memory_kinds()
    key = jax.random.PRNGKey(5)
    tiers, ccfg = _tiers(key)
    off = collab.offload_host_tier(tiers)
    assert off.host_w1.sharding.memory_kind == host_kind
    x = jax.random.normal(key, (2, 16), jnp.float32)
    ti = jnp.asarray([[0, 1], [2, 3]])
    tw = jnp.asarray([[0.5, 0.5], [0.6, 0.4]], jnp.float32)
    # memory-space transfers are compile-time placements: jit required
    step_off = jax.jit(lambda t, l, x, ti, tw:
                       collab.collaborative_moe_offloaded(t, l, x, ti, tw,
                                                          ccfg))
    for layer in (0, 1, 2):          # covered cold/warm + beyond coverage
        for rep in range(2):
            y_ref, tiers, s_ref = collab.collaborative_moe(
                tiers, jnp.int32(layer), x, ti, tw, ccfg)
            y_off, off, s_off = step_off(off, jnp.int32(layer), x, ti, tw)
            np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_off),
                                       rtol=1e-5, atol=1e-5)
            assert int(s_ref["hits"]) == int(s_off["hits"])
    # slot buffers converged identically through post-fetches
    np.testing.assert_allclose(np.asarray(tiers.slot_w1),
                               np.asarray(off.slot_w1), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("policy", ["lru", "fifo", "random"])
def test_grouped_matches_seed_per_assignment_path(policy):
    """Parity: the grouped gmm-backed execution must match the retained
    seed per-assignment path numerically across cold/warm/beyond-coverage
    transitions (f32 weights -> tight tolerance), on traces without
    duplicate picks (where the seed path is well-defined)."""
    key = jax.random.PRNGKey(11)
    tiers_g, ccfg = _tiers(key, policy=policy)
    tiers_r, _ = _tiers(key, ccfg=ccfg, policy=policy)
    rng = np.random.default_rng(0)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    tw = jnp.asarray([[0.6, 0.4], [0.5, 0.5]], jnp.float32)
    for layer in (0, 1, 2):
        for rep in range(3):
            picks = rng.permutation(4)[:4].reshape(2, 2)   # dup-free
            ti = jnp.asarray(picks)
            y_g, tiers_g, s_g = collab.collaborative_moe(
                tiers_g, jnp.int32(layer), x, ti, tw, ccfg)
            y_r, tiers_r, s_r = collab.collaborative_moe_reference(
                tiers_r, jnp.int32(layer), x, ti, tw, ccfg)
            np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_r),
                                       rtol=1e-5, atol=1e-5)
            for k in ("hits", "accesses", "host_flops_assignments"):
                assert int(s_g[k]) == int(s_r[k]), (k, layer, rep)
            # the grouped post-fetch copies only experts that survive the
            # step (the seed also copied within-step evictions): <=
            assert int(s_g["fetched_experts"]) <= int(s_r["fetched_experts"])
            np.testing.assert_allclose(np.asarray(tiers_g.slot_w1),
                                       np.asarray(tiers_r.slot_w1),
                                       rtol=1e-6, atol=1e-6)
            assert np.array_equal(np.asarray(tiers_g.state.tags),
                                  np.asarray(tiers_r.state.tags))


def test_grouped_handles_duplicate_picks_across_tokens():
    """Two concurrent tokens picking the same cold expert: the grouped
    path computes both from the host tier (the seed path read the stale
    slot buffer for the second — the bookkeeping insert of the first
    masqueraded as a cache hit)."""
    key = jax.random.PRNGKey(0)
    tiers, ccfg = _tiers(key)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    ti = jnp.asarray([[0, 1], [0, 2]])                     # expert 0 twice
    tw = jnp.asarray([[0.6, 0.4], [0.5, 0.5]], jnp.float32)
    y, tiers, stats = collab.collaborative_moe(
        tiers, jnp.int32(0), x, ti, tw, ccfg)
    ref = _dense_ref(tiers, 0, x, ti, tw)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)
    # bookkeeping keeps the paper's sequential-semantics hit counter; the
    # post-fetch copies only the experts resident AFTER the step (expert 1
    # is inserted then evicted within the step -> not copied)
    assert int(stats["hits"]) == 1 and int(stats["fetched_experts"]) == 2


def test_active_mask_excludes_padded_rows():
    """Inactive rows (padded scheduler slots) produce zero output, leave
    the cache untouched and are excluded from the stats."""
    key = jax.random.PRNGKey(4)
    tiers, ccfg = _tiers(key)
    x = jax.random.normal(key, (2, 16), jnp.float32)
    ti = jnp.asarray([[0, 1], [2, 3]])
    tw = jnp.asarray([[0.5, 0.5], [0.5, 0.5]], jnp.float32)
    active = jnp.asarray([True, False])
    y, tiers, stats = collab.collaborative_moe(
        tiers, jnp.int32(0), x, ti, tw, ccfg, active=active)
    assert int(stats["accesses"]) == 2 and int(stats["fetched_experts"]) == 2
    assert (np.asarray(y[1]) == 0).all()
    tags = set(np.asarray(tiers.state.tags[0]).tolist())
    assert 2 not in tags and 3 not in tags                  # row 1 masked
    ref = _dense_ref(tiers, 0, x, ti, tw)
    np.testing.assert_allclose(np.asarray(y[0]), ref[0], rtol=2e-4,
                               atol=2e-4)


def test_static_random_preload():
    key = jax.random.PRNGKey(3)
    ccfg = CacheConfig(num_indexes=3, num_ways=2, policy="random")
    tiers, _ = _tiers(key, ccfg=ccfg, policy="random")
    tags = np.asarray(tiers.state.tags)
    for l in range(3):
        for w in range(2):
            e = int(tags[l, w])
            np.testing.assert_array_equal(
                np.asarray(tiers.slot_w1[l * 2 + w]),
                np.asarray(tiers.host_w1[l, e]))
