"""Request-centric serving API tests: per-slot sampling, cache-warming
chunked prefill, streaming lifecycle, submit-time validation, and the
``build()`` façade.

The acceptance pair for the chunked prefill redesign: generated tokens
bit-identical to the bypass-prefill path, and a strictly higher
first-decode-step demand hit rate on a long (>= 64-token) prompt.
"""
import jax
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.models import init_params
from repro.serving import GREEDY, SamplingParams, build
from repro.serving.sampling import batch_arrays, sample_tokens


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _build(cfg, params, **serving):
    serving.setdefault("capacity", 96)
    return build(cfg, cache=dict(num_ways=4), serving=serving,
                 params=params, seed=0)


def _prompts(cfg, lengths, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
            for p in lengths]


# ---------------------------------------------------------------------------
# vectorized per-slot sampler
# ---------------------------------------------------------------------------

def test_sampler_vectorized_matches_per_row_reference():
    """One [T] params batch == each row sampled alone with its own
    filters and key: greedy rows argmax, sampled rows reproduce a numpy
    re-implementation of the temperature/top-k/top-p pipeline."""
    rng = np.random.default_rng(0)
    V = 64
    logits = rng.normal(size=(4, V)).astype(np.float32) * 3
    params = [GREEDY,
              SamplingParams(greedy=False, temperature=1.0),
              SamplingParams(greedy=False, temperature=0.5, top_k=5),
              SamplingParams(greedy=False, temperature=2.0, top_p=0.7)]
    keys = np.stack([np.asarray(jax.random.PRNGKey(100 + i))
                     for i in range(4)])
    g, t, k, p = batch_arrays(params)
    out = np.asarray(sample_tokens(logits, g, t, k, p, keys))

    assert out[0] == int(np.argmax(logits[0]))
    for i in (1, 2, 3):
        sp = params[i]
        scaled = logits[i] / sp.temperature
        if sp.top_k:
            kth = np.sort(scaled)[::-1][sp.top_k - 1]
            scaled = np.where(scaled < kth, -np.inf, scaled)
        if sp.top_p < 1.0:
            srt = np.sort(scaled)[::-1]
            ps = np.exp(srt - srt.max())
            ps /= ps.sum()
            keep = (np.cumsum(ps) - ps) < sp.top_p
            thresh = srt[keep].min()
            scaled = np.where(scaled < thresh, -np.inf, scaled)
        ref = int(jax.random.categorical(keys[i], scaled))
        assert out[i] == ref, (i, out[i], ref)
        # the filters really cut: sampled token is inside the kept set
        assert np.isfinite(scaled[out[i]])


def test_per_slot_sampling_isolated_and_seed_reproducible(setup):
    """Two slots with different SamplingParams decode together: the
    greedy slot's tokens are invariant to the sampled slot's seed (slots
    never share randomness, and sampling changes no logits), while the
    sampled slot reproduces per seed and moves across seeds."""
    cfg, params = setup
    prompts = _prompts(cfg, [6, 7])

    def run(seed):
        _, sched = _build(cfg, params, max_batch=2)
        a = sched.submit(prompts[0], max_new_tokens=8)      # greedy
        b = sched.submit(prompts[1], max_new_tokens=8,
                         sampling=SamplingParams(greedy=False,
                                                 temperature=6.0,
                                                 seed=seed))
        outs = sched.run()
        return outs[a.rid], outs[b.rid]

    a1, b1 = run(5)
    a2, b2 = run(5)
    a3, b3 = run(17)
    np.testing.assert_array_equal(b1, b2)       # per-request seed: exact
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(a1, a3)       # greedy row never budges
    assert not np.array_equal(b1, b3), \
        "different seeds should draw different high-temperature paths"


# ---------------------------------------------------------------------------
# cache-warming chunked prefill
# ---------------------------------------------------------------------------

def test_chunked_prefill_bit_identical_tokens(setup):
    """Acceptance: with chunked prefill enabled, generated tokens are
    BIT-identical to the bypass-prefill path — warming changes residency
    and the prefill_* channel, never numerics."""
    cfg, params = setup
    prompts = _prompts(cfg, [64, 70])

    def run(prefill_chunk):
        _, sched = _build(cfg, params, max_batch=2,
                          prefill_chunk=prefill_chunk)
        for p in prompts:
            sched.submit(p, max_new_tokens=12)
        return sched.run(), sched.stats

    outs_b, s_b = run(0)
    outs_c, s_c = run(8)
    assert sorted(outs_b) == sorted(outs_c)
    for rid in outs_b:
        np.testing.assert_array_equal(outs_b[rid], outs_c[rid])
    # the warming is real and lives in its own stat channel
    assert s_b.prefill_accesses == s_b.prefill_tokens == 0
    assert s_c.prefill_tokens == sum(len(p) for p in prompts)
    assert s_c.prefill_accesses == \
        s_c.prefill_tokens * cfg.num_layers * cfg.moe.top_k
    assert s_c.prefill_chunks == sum(-(-len(p) // 8) for p in prompts)
    # decode demand channel identical: same steps, same accesses
    assert s_c.accesses == s_b.accesses and s_c.steps == s_b.steps


def test_chunked_prefill_warms_first_decode_step(setup):
    """Acceptance: on long (>= 64-token) prompts the FIRST decode step's
    demand hit rate is strictly higher with chunked prefill — the prompt's
    routing warmed the shared cache before decode touched it."""
    cfg, params = setup
    prompts = _prompts(cfg, [64, 70])

    def first_step_hit_rate(prefill_chunk):
        eng, _ = _build(cfg, params, max_batch=2,
                        prefill_chunk=prefill_chunk)
        state = eng.init_slots()
        next_tok = np.zeros((2, 1), np.int32)
        for t, p in enumerate(prompts):
            tok, one = eng.prefill_request(p)
            state = eng.write_slot(state, one, t)
            next_tok[t, 0] = tok
        before = eng.stats
        eng.decode_batch(next_tok, state, np.ones(2, bool))
        after = eng.stats
        acc = after.accesses - before.accesses
        assert acc == 2 * cfg.num_layers * cfg.moe.top_k
        return (after.hits - before.hits) / acc

    cold = first_step_hit_rate(0)
    warm = first_step_hit_rate(8)
    assert warm > cold, (warm, cold)


def test_prefill_is_the_backbone_with_trace_emission(setup):
    """There is ONE prefill implementation: the engine routes through
    ``transformer.backbone(mode="prefill")``, whose ``want_trace`` flag
    emits the routing trace. Pins (a) bitwise KV + logits parity between
    the engine prefill and the backbone, (b) that emitting the trace
    perturbs NOTHING (same KV, same logits bit for bit), and (c) that the
    emitted trace is exactly the routing of the emitted h2."""
    import jax.numpy as jnp
    from repro.models import model as model_lib
    from repro.models import transformer
    from repro.models.moe import route
    cfg, params = setup
    eng, _ = _build(cfg, params, prefill_chunk=0)
    prompt = _prompts(cfg, [24])[0]
    cap = eng.ecfg.capacity
    padded = np.concatenate(
        [prompt, np.zeros(cap - len(prompt), np.int32)])[None]
    lg_engine, st_engine = eng.prefill(prompt[None])
    _, st_backbone = model_lib.prefill(
        params, {"tokens": jnp.asarray(padded)}, cfg)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        st_engine["scan"], st_backbone["scan"])
    # the first-token logits: the backbone's hidden state at the last
    # REAL prompt position produces bitwise the engine's prefill logits
    x, _, _, trace = transformer.backbone(
        params, {"tokens": jnp.asarray(padded)}, cfg, "prefill",
        remat=False, want_trace=True)
    lg_backbone = transformer.lm_logits(
        params, x[:, len(prompt) - 1:len(prompt)], cfg)
    np.testing.assert_array_equal(np.asarray(lg_engine),
                                  np.asarray(lg_backbone))
    # trace emission changes nothing: the trace-bearing padded prefill
    # returns the SAME logits and KV as the bypass call above
    lg_t, st_t, tr = eng._padded_prefill(prompt[None], want_trace=True)
    np.testing.assert_array_equal(np.asarray(lg_engine), np.asarray(lg_t))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        st_engine["scan"], st_t["scan"])
    # and the trace is self-consistent: top_i/top_w ARE the routing of h2
    L, K = cfg.num_layers, cfg.moe.top_k
    assert tr["top_i"].shape == (L, 1, cap, K)
    for layer in (0, L - 1):
        lp = jax.tree.map(lambda a: a[layer], params["scan"]["s0"])
        _, ti, tw = route(lp["moe"]["router"],
                          tr["h2"][layer].reshape(cap, -1), K)
        np.testing.assert_array_equal(np.asarray(ti),
                                      np.asarray(tr["top_i"][layer, 0]))
        np.testing.assert_array_equal(np.asarray(tw),
                                      np.asarray(tr["top_w"][layer, 0]))


def test_prefill_ticket_resumes_and_matches_monolithic(setup):
    """start_prefill/advance_prefill are the resumable decomposition of
    prefill_chunked: advancing a ticket one chunk at a time accumulates
    exactly the same prefill channel (and the same logits/state) as the
    one-call drain, and the cursor/done/remaining bookkeeping is sane."""
    cfg, params = setup
    prompt = _prompts(cfg, [22])[0]               # 3 chunks of 8

    eng_a, _ = _build(cfg, params)
    lg_a, st_a = eng_a.prefill_chunked(prompt, chunk=8)
    s_a = eng_a.stats

    eng_b, _ = _build(cfg, params)
    ticket = eng_b.start_prefill(prompt, chunk=8)
    assert ticket.n_chunks == 3 and ticket.remaining == 3
    assert not ticket.done
    np.testing.assert_array_equal(np.asarray(lg_a),
                                  np.asarray(ticket.logits))
    steps = 0
    while not eng_b.advance_prefill(ticket, 1):
        steps += 1
        assert ticket.cursor == steps
    assert steps == 2 and ticket.done and ticket.remaining == 0
    s_b = eng_b.stats
    for k in ("prefill_hits", "prefill_accesses", "prefill_fetched",
              "prefill_tokens", "prefill_chunks"):
        assert getattr(s_a, k) == getattr(s_b, k), k
    assert s_b.prefill_tokens == 22 and s_b.prefill_chunks == 3
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        st_a, ticket.state)
    # advancing a done ticket is a no-op
    assert eng_b.advance_prefill(ticket, 5)
    assert eng_b.stats.prefill_chunks == 3
    # bypass geometry: chunk=0 tickets are born done, no trace held
    eng_c, _ = _build(cfg, params, prefill_chunk=0)
    t0 = eng_c.start_prefill(prompt)
    assert t0.done and t0.n_chunks == 0 and t0.top_i is None
    assert eng_c.stats.prefill_accesses == 0


def test_prefill_chunk_size_does_not_change_residency_effect(setup):
    """Chunk size is a pipelining knob, not a semantics knob: warming in
    4-token and 16-token chunks replays the same routing trace, so the
    prefill channel counts the same accesses and tokens."""
    cfg, params = setup
    prompt = _prompts(cfg, [33])[0]          # not a multiple of either

    def run(chunk):
        eng, _ = _build(cfg, params, prefill_chunk=chunk)
        eng.prefill_request(prompt)
        return eng.stats

    s4, s16 = run(4), run(16)
    assert s4.prefill_accesses == s16.prefill_accesses > 0
    assert s4.prefill_tokens == s16.prefill_tokens == 33
    assert s4.prefill_chunks == 9 and s16.prefill_chunks == 3


# ---------------------------------------------------------------------------
# streaming lifecycle
# ---------------------------------------------------------------------------

def test_stream_ordering_and_termination(setup):
    """stream() yields (rid, token, done) in generation order per request;
    exactly one done=True per request, as its final event; the streamed
    tokens equal the requests' outputs."""
    cfg, params = setup
    _, sched = _build(cfg, params, max_batch=2)
    reqs = [sched.submit(p, max_new_tokens=4 + i)
            for i, p in enumerate(_prompts(cfg, [5, 9, 6]))]
    events = list(sched.stream())

    by_rid = {r.rid: [] for r in reqs}
    for rid, tok, done in events:
        by_rid[rid].append((tok, done))
    for i, r in enumerate(reqs):
        toks = [t for t, _ in by_rid[r.rid]]
        dones = [d for _, d in by_rid[r.rid]]
        assert toks == list(r.output)
        assert len(toks) == 4 + i
        assert dones == [False] * (len(toks) - 1) + [True]
    # continuous batching: the two admitted requests' events interleave
    # (neither request's stream completes before the other's starts)
    r0, r1 = reqs[0].rid, reqs[1].rid
    order = [rid for rid, _, _ in events if rid in (r0, r1)]
    assert order.index(r1) < len(by_rid[r0]) + len(by_rid[r1]) - 1
    assert {r0, r1} <= set(order[:4])


def test_stop_sequences_terminate_early(setup):
    """A stop sequence (taken from a reference greedy run) terminates the
    request at the match, before max_new_tokens."""
    cfg, params = setup
    prompt = _prompts(cfg, [8])[0]
    _, sched = _build(cfg, params)
    ref = sched.submit(prompt, max_new_tokens=10)
    full = sched.run()[ref.rid]

    stop = tuple(int(t) for t in full[3:5])      # tokens 3..4 of the run
    # the stop point: FIRST suffix match of the sequence in the greedy
    # stream (greedy repetition may surface it before position 5)
    exp = next(i + 1 for i in range(1, len(full))
               if tuple(int(t) for t in full[i - 1:i + 1]) == stop)
    _, sched2 = _build(cfg, params)
    r = sched2.submit(prompt, max_new_tokens=10, stop_sequences=[stop])
    out = sched2.run()[r.rid]
    assert len(out) == exp <= 5                   # stopped at the match
    np.testing.assert_array_equal(out, full[:exp])
    assert tuple(int(t) for t in out[-2:]) == stop


def test_on_token_callback_matches_stream(setup):
    cfg, params = setup
    _, sched = _build(cfg, params)
    seen = []
    r = sched.submit(_prompts(cfg, [6])[0], max_new_tokens=5,
                     on_token=lambda tok, done: seen.append((tok, done)))
    events = [(tok, done) for rid, tok, done in sched.stream()
              if rid == r.rid]
    assert seen == events
    assert [t for t, _ in seen] == list(r.output)


# ---------------------------------------------------------------------------
# submit-time validation + façade
# ---------------------------------------------------------------------------

def test_submit_validates_prompt_against_capacity(setup):
    """Oversized requests fail fast at submit() with a clear ValueError —
    not mid-run inside prefill after other requests already decoded."""
    cfg, params = setup
    _, sched = _build(cfg, params)                # capacity 96
    with pytest.raises(ValueError, match="capacity"):
        sched.submit(np.arange(90, dtype=np.int32), max_new_tokens=10)
    with pytest.raises(ValueError, match="at least one token"):
        sched.submit(np.zeros(0, np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(np.arange(4, dtype=np.int32), max_new_tokens=0)
    # per-request paths reject prompt BATCHES instead of silently
    # concatenating the rows into one prompt — at submit() and at the
    # engine primitive
    with pytest.raises(ValueError, match="ONE prompt"):
        sched.submit(np.zeros((2, 8), np.int32), max_new_tokens=4)
    eng, _ = _build(cfg, params)
    with pytest.raises(ValueError, match="ONE prompt"):
        eng.prefill_request(np.zeros((2, 8), np.int32))
    # boundary: plen + max_new_tokens == capacity is admissible and runs
    r = sched.submit(np.arange(88, dtype=np.int32), max_new_tokens=8)
    assert len(sched.run()[r.rid]) == 8


def test_build_facade_resolves_defaults(setup):
    cfg, _ = setup
    eng, sched = build("mixtral-8x7b", serving=dict(max_batch=2,
                                                    capacity=48))
    assert eng.ecfg.cache.num_indexes == eng.cfg.num_layers
    assert eng.ecfg.cache.num_ways == 2
    assert eng.ecfg.max_batch == 2 and sched.num_slots == 2
    assert eng.ecfg.prefill_chunk > 0             # warming on by default
    with pytest.raises(ValueError, match="homogeneous"):
        build("gemma3-4b")

    r = sched.submit(np.arange(6, dtype=np.int32), max_new_tokens=3)
    outs = sched.run()
    assert len(outs[r.rid]) == 3
    assert sched.stats.prefill_tokens == 6        # admission warmed


def test_sampling_params_validate():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(greedy=False, temperature=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
