"""Continuous-batching scheduler + batched engine behaviour tests.

The acceptance bar for the batched serving core: >=4 concurrent requests
decode through ONE shared expert cache in one padded step; padded slots
are bitwise-invisible to active rows; a batched step computes the same
logits as independent single-request decodes (bf16 tolerance); slots
recycle so more requests than slots drain to completion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, ContinuousBatchingScheduler, \
    EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _engine(cfg, params, slots=4, capacity=64):
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    return CollaborativeEngine(
        cfg, params, EngineConfig(cache=ccfg, max_batch=slots,
                                  capacity=capacity),
        key=jax.random.PRNGKey(3))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9)))
            .astype(np.int32) for _ in range(n)]


def test_four_concurrent_requests_share_one_cache(setup):
    """>=4 requests in flight simultaneously, one shared expert cache."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=4)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=6) for p in _prompts(cfg, 4)]
    sched.step()
    assert sched.num_active == 4                  # all four decode together
    outs = sched.run()
    assert sorted(outs) == [r.rid for r in reqs]
    for r in reqs:
        assert len(outs[r.rid]) == 6
    stats = sched.stats
    # every decode step served the full batch through the one cache
    assert stats.accesses == stats.hits + stats.host_assignments
    assert stats.tokens == 4 * 5                  # 5 decode ticks per request
    assert 0.0 <= stats.hit_rate <= 1.0
    assert stats.requests_submitted == stats.requests_finished == 4


def test_slots_recycle_when_requests_outnumber_slots(setup):
    cfg, params = setup
    eng = _engine(cfg, params, slots=2)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=3 + i)
            for i, p in enumerate(_prompts(cfg, 5, seed=1))]
    outs = sched.run()
    assert len(outs) == 5
    for i, r in enumerate(reqs):
        assert len(outs[r.rid]) == 3 + i
    # with 2 slots, 5 requests were never all in flight, yet all completed
    assert sched.queue == type(sched.queue)()


def test_padded_slots_are_bitwise_invisible(setup):
    """Garbage in inactive slots (tokens, KV positions) must not change
    active rows' logits AT ALL — the isolation that makes continuous
    batching correct."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=4)
    prompt = _prompts(cfg, 1)[0]
    tok, one_state = eng.prefill_request(prompt)

    def run(junk_tok, junk_pos):
        state = eng.init_slots()
        state = eng.write_slot(state, one_state, 0)
        state["pos"] = state["pos"].at[1:].set(junk_pos)
        fast0 = eng.fast                               # snapshot tiers
        tokens = np.full((4, 1), junk_tok, np.int32)
        tokens[0, 0] = tok
        active = np.array([True, False, False, False])
        logits, _, fast, stats = eng._decode(
            jnp.asarray(tokens), state, fast0, jnp.asarray(active))
        return (np.asarray(logits[0, 0]), jax.tree.map(np.asarray, fast),
                {k: int(np.asarray(v).sum()) for k, v in stats.items()})

    # donation invalidates eng.fast: rebuild the engine per variant
    l1, f1, s1 = run(junk_tok=7, junk_pos=0)
    eng = _engine(cfg, params, slots=4)
    tok2, one_state = eng.prefill_request(prompt)
    assert tok2 == tok
    l2, f2, s2 = run(junk_tok=301, junk_pos=13)
    np.testing.assert_array_equal(l1, l2)
    jax.tree.map(np.testing.assert_array_equal, f1, f2)
    assert s1 == s2
    assert s1["accesses"] == cfg.num_layers * cfg.moe.top_k  # active row only


def test_batched_step_matches_single_request_logits(setup):
    """One padded 4-way decode step == four independent 1-way decode steps
    (same KV state, same cache-off... identical weights), row by row,
    within bf16 tolerance. Verifies no cross-slot leakage through
    attention, routing or the grouped MoE dispatch."""
    cfg, params = setup
    prompts = _prompts(cfg, 4, seed=2)

    # batched: prefill each request into its slot, one decode step
    eng = _engine(cfg, params, slots=4)
    state = eng.init_slots()
    next_tok = np.zeros((4, 1), np.int32)
    for t, p in enumerate(prompts):
        tok, one_state = eng.prefill_request(p)
        state = eng.write_slot(state, one_state, t)
        next_tok[t, 0] = tok
    logits_b, _ = eng.decode_batch(next_tok, state, np.ones(4, bool))
    logits_b = np.asarray(logits_b[:, 0], np.float32)

    # solo: same step for each request alone
    for t, p in enumerate(prompts):
        eng1 = _engine(cfg, params, slots=1)
        tok, one_state = eng1.prefill_request(p)
        assert tok == next_tok[t, 0]
        state1 = eng1.init_slots()
        state1 = eng1.write_slot(state1, one_state, 0)
        logits_s, _ = eng1.decode_batch(np.asarray([[tok]], np.int32),
                                        state1, np.ones(1, bool))
        np.testing.assert_allclose(
            logits_b[t], np.asarray(logits_s[0, 0], np.float32),
            rtol=2e-2, atol=2e-2)


def test_staggered_positions_decode_correctly(setup):
    """Slots at different KV positions (different prompt lengths) coexist:
    the scheduler output for each request equals its solo scheduler run."""
    cfg, params = setup
    prompts = [np.arange(4, dtype=np.int32), np.arange(9, dtype=np.int32),
               np.arange(6, dtype=np.int32)]
    solo = []
    for p in prompts:
        eng1 = _engine(cfg, params, slots=1)
        s1 = ContinuousBatchingScheduler(eng1)
        r = s1.submit(p, max_new_tokens=4)
        solo.append(s1.run()[r.rid])
    eng = _engine(cfg, params, slots=3)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=4) for p in prompts]
    outs = sched.run()
    for r, s in zip(reqs, solo):
        # first token comes from the (batch-independent) prefill: exact.
        assert outs[r.rid][0] == s[0]
        assert len(outs[r.rid]) == len(s)
