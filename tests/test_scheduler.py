"""Continuous-batching scheduler + batched engine behaviour tests.

The acceptance bar for the batched serving core: >=4 concurrent requests
decode through ONE shared expert cache in one padded step; padded slots
are bitwise-invisible to active rows; a batched step computes the same
logits as independent single-request decodes (bf16 tolerance); slots
recycle so more requests than slots drain to completion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, ContinuousBatchingScheduler, \
    EngineConfig, QueueFull, Request


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _engine(cfg, params, slots=4, capacity=64, **ecfg):
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    return CollaborativeEngine(
        cfg, params, EngineConfig(cache=ccfg, max_batch=slots,
                                  capacity=capacity, **ecfg),
        key=jax.random.PRNGKey(3))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9)))
            .astype(np.int32) for _ in range(n)]


def test_four_concurrent_requests_share_one_cache(setup):
    """>=4 requests in flight simultaneously, one shared expert cache."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=4)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=6) for p in _prompts(cfg, 4)]
    sched.step()
    assert sched.num_active == 4                  # all four decode together
    outs = sched.run()
    assert sorted(outs) == [r.rid for r in reqs]
    for r in reqs:
        assert len(outs[r.rid]) == 6
    stats = sched.stats
    # every decode step served the full batch through the one cache
    assert stats.accesses == stats.hits + stats.host_assignments
    assert stats.tokens == 4 * 5                  # 5 decode ticks per request
    # first-token accounting: each request's prefill-sampled token counts
    # once, so token totals match what the requests actually streamed
    assert stats.first_tokens == 4
    assert stats.generated_tokens == 4 * 6 \
        == sum(len(o) for o in outs.values())
    assert 0.0 <= stats.hit_rate <= 1.0
    assert stats.requests_submitted == stats.requests_finished == 4


def test_slots_recycle_when_requests_outnumber_slots(setup):
    cfg, params = setup
    eng = _engine(cfg, params, slots=2)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=3 + i)
            for i, p in enumerate(_prompts(cfg, 5, seed=1))]
    outs = sched.run()
    assert len(outs) == 5
    for i, r in enumerate(reqs):
        assert len(outs[r.rid]) == 3 + i
    # with 2 slots, 5 requests were never all in flight, yet all completed
    assert sched.queue == type(sched.queue)()


def test_padded_slots_are_bitwise_invisible(setup):
    """Garbage in inactive slots (tokens, KV positions) must not change
    active rows' logits AT ALL — the isolation that makes continuous
    batching correct."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=4)
    prompt = _prompts(cfg, 1)[0]
    tok, one_state = eng.prefill_request(prompt)

    def run(junk_tok, junk_pos):
        state = eng.init_slots()
        state = eng.write_slot(state, one_state, 0)
        state["pos"] = state["pos"].at[1:].set(junk_pos)
        fast0 = eng.fast                               # snapshot tiers
        tokens = np.full((4, 1), junk_tok, np.int32)
        tokens[0, 0] = tok
        active = np.array([True, False, False, False])
        logits, _, fast, stats = eng._decode(
            jnp.asarray(tokens), state, fast0, jnp.asarray(active))
        return (np.asarray(logits[0, 0]), jax.tree.map(np.asarray, fast),
                {k: int(np.asarray(v).sum()) for k, v in stats.items()})

    # donation invalidates eng.fast: rebuild the engine per variant
    l1, f1, s1 = run(junk_tok=7, junk_pos=0)
    eng = _engine(cfg, params, slots=4)
    tok2, one_state = eng.prefill_request(prompt)
    assert tok2 == tok
    l2, f2, s2 = run(junk_tok=301, junk_pos=13)
    np.testing.assert_array_equal(l1, l2)
    jax.tree.map(np.testing.assert_array_equal, f1, f2)
    assert s1 == s2
    assert s1["accesses"] == cfg.num_layers * cfg.moe.top_k  # active row only


def test_batched_step_matches_single_request_logits(setup):
    """One padded 4-way decode step == four independent 1-way decode steps
    (same KV state, same cache-off... identical weights), row by row,
    within bf16 tolerance. Verifies no cross-slot leakage through
    attention, routing or the grouped MoE dispatch."""
    cfg, params = setup
    prompts = _prompts(cfg, 4, seed=2)

    # batched: prefill each request into its slot, one decode step
    eng = _engine(cfg, params, slots=4)
    state = eng.init_slots()
    next_tok = np.zeros((4, 1), np.int32)
    for t, p in enumerate(prompts):
        tok, one_state = eng.prefill_request(p)
        state = eng.write_slot(state, one_state, t)
        next_tok[t, 0] = tok
    logits_b, _ = eng.decode_batch(next_tok, state, np.ones(4, bool))
    logits_b = np.asarray(logits_b[:, 0], np.float32)

    # solo: same step for each request alone
    for t, p in enumerate(prompts):
        eng1 = _engine(cfg, params, slots=1)
        tok, one_state = eng1.prefill_request(p)
        assert tok == next_tok[t, 0]
        state1 = eng1.init_slots()
        state1 = eng1.write_slot(state1, one_state, 0)
        logits_s, _ = eng1.decode_batch(np.asarray([[tok]], np.int32),
                                        state1, np.ones(1, bool))
        np.testing.assert_allclose(
            logits_b[t], np.asarray(logits_s[0, 0], np.float32),
            rtol=2e-2, atol=2e-2)


def test_cancel_mid_decode_frees_slot_and_admits_waiting(setup):
    """cancel(rid) mid-decode: the request stops decoding immediately, a
    terminal (rid, token, done=True) event is emitted, and the freed slot
    admits a waiting request on the next tick."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=2)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=8)
            for p in _prompts(cfg, 3, seed=3)]
    sched.step()                                  # r0, r1 in flight
    assert sched.num_active == 2 and len(sched.queue) == 1
    victim = reqs[0]
    n_before = len(victim.generated)
    assert sched.cancel(victim.rid)
    assert victim.done and victim.cancelled
    # slot freed immediately; no further tokens for the cancelled request
    assert sched.num_active == 1
    finished, events = sched._tick()
    # -1 sentinel: every real token was already streamed exactly once
    assert events[0] == (victim.rid, -1, True)
    assert victim in finished                     # step() reports it done
    assert len(victim.generated) == n_before      # token stream rejected
    # the waiting request took the freed slot on that same tick
    assert sched.num_active == 2
    assert any(s is not None and s.rid == reqs[2].rid for s in sched.slots)
    outs = sched.run()
    assert sorted(outs) == [r.rid for r in reqs]
    assert len(outs[victim.rid]) == n_before < 8
    for r in (reqs[1], reqs[2]):
        assert len(outs[r.rid]) == 8
    # cancelling again (or an unknown rid) is a no-op, not an error
    assert not sched.cancel(victim.rid)
    assert not sched.cancel(10_000)


def test_cancel_from_on_token_callback_at_admission(setup):
    """An on_token handler that cancels its own request on the FIRST
    token (content-filter style) must take effect: the request is live in
    its slot when the callback fires, so cancel() frees it and no decode
    tokens follow."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit(
        _prompts(cfg, 1, seed=7)[0], max_new_tokens=8,
        on_token=lambda tok, done: done or sched.cancel(req.rid))
    outs = sched.run()
    assert req.cancelled
    assert len(outs[req.rid]) == 1                # the prefill token only
    assert sched.stats.requests_finished == 1


def test_cancel_finished_request_awaiting_retirement_is_noop(setup):
    """A request that finished on the last tick but still occupies its
    slot (retirement happens at the next tick's start) already streamed
    its terminal event — cancel() must refuse rather than emit a second
    done=True."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit(_prompts(cfg, 1, seed=6)[0], max_new_tokens=2)
    sched.step()                          # admit + first decode -> done
    assert req.done and sched.slots[0] is req
    assert not sched.cancel(req.rid)
    assert not sched._pending_events
    assert not req.cancelled
    sched.step()                          # normal retirement
    assert sched.finished == [req]


def test_cancel_queued_request_and_stream_terminal_event(setup):
    """A queued request cancels without ever decoding: stream() delivers
    exactly one event for it — (rid, -1, done=True) — and on_token fires
    once with done=True."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng)
    seen = []
    r0 = sched.submit(_prompts(cfg, 1, seed=4)[0], max_new_tokens=3)
    rq = sched.submit(_prompts(cfg, 1, seed=5)[0], max_new_tokens=3,
                      on_token=lambda tok, done: seen.append((tok, done)))
    assert sched.cancel(rq.rid)                   # still queued: no tokens
    assert seen == [(-1, True)]
    events = list(sched.stream())
    ev_rq = [e for e in events if e[0] == rq.rid]
    assert ev_rq == [(rq.rid, -1, True)]
    done_flags = [e for e in events if e[0] == r0.rid]
    assert len(done_flags) == 3 and done_flags[-1][2]
    assert sorted(r.rid for r in sched.finished) == [r0.rid, rq.rid]
    assert rq.output.size == 0


def test_request_equality_is_identity(setup):
    """Two distinct requests with EQUAL prompts must compare unequal
    without touching the ndarray (a dataclass-generated __eq__ would
    raise "truth value of an array is ambiguous" in `req in queue` /
    list.remove): rid is the key, identity the semantics."""
    prompt = np.arange(6, dtype=np.int32)
    r1 = Request(0, prompt.copy(), 4)
    r2 = Request(1, prompt.copy(), 4)
    assert r1 != r2                               # no ValueError
    assert r1 == r1
    assert r1 in [r2, r1] and r1 not in [r2]
    lst = [r1, r2]
    lst.remove(r2)
    assert lst == [r1]


# ---------------------------------------------------------------------------
# overlapped chunk-interleaved admission (the PREFILLING phase)
# ---------------------------------------------------------------------------

def _submit_mixed(sched, cfg, long_len=48, seed=11):
    """Two short established requests (fully warmed and decoding) + one
    long-prompt newcomer still in the queue."""
    rng = np.random.default_rng(seed)
    est = [sched.submit(rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=16) for _ in range(2)]
    sched.step()                                  # admit both
    while sched.prefill_pending:                  # drain their short warms
        sched.step()
    newcomer = sched.submit(rng.integers(0, cfg.vocab_size, long_len),
                            max_new_tokens=6)
    return est, newcomer


def test_overlapped_admission_tokens_bit_identical(setup):
    """Acceptance: with overlap enabled, EVERY request's tokens are
    bit-identical to the synchronous-admission path — warming pace moves
    residency and latency, never numerics."""
    cfg, params = setup

    def run(admit_chunks):
        eng = _engine(cfg, params, slots=3, capacity=96, prefill_chunk=4,
                      admit_chunks_per_tick=admit_chunks)
        sched = ContinuousBatchingScheduler(eng)
        est, newcomer = _submit_mixed(sched, cfg)
        return sched.run(), sched.stats

    outs_sync, s_sync = run(0)
    outs_over, s_over = run(1)
    assert sorted(outs_sync) == sorted(outs_over)
    for rid in outs_sync:
        np.testing.assert_array_equal(outs_sync[rid], outs_over[rid])
    # both paths replay the same warm chunks, just paced differently
    assert s_over.prefill_chunks == s_sync.prefill_chunks
    assert s_over.prefill_accesses == s_sync.prefill_accesses


def test_overlapped_admission_decodes_established_while_warming(setup):
    """The head-of-line fix itself: while the newcomer's slot is in the
    PREFILLING phase, the established requests decode a token on every
    tick and the newcomer emits nothing beyond its prefill-sampled first
    token; its warm replay advances admit_chunks_per_tick chunks/tick."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=3, capacity=96, prefill_chunk=4,
                  admit_chunks_per_tick=1)
    sched = ContinuousBatchingScheduler(eng)
    est, newcomer = _submit_mixed(sched, cfg)     # 48 tokens -> 12 chunks
    est_before = [len(r.generated) for r in est]
    chunks_before = eng.stats.prefill_chunks

    sched.step()                                  # admission tick
    assert sched.prefill_pending == 1
    assert sched.stats.prefill_pending == 1
    assert len(newcomer.generated) == 1           # the prefill token only
    assert eng.stats.prefill_chunks == chunks_before + 1
    warm_ticks = 0
    while sched.prefill_pending:
        n_est = [len(r.generated) for r in est]
        sched.step()
        warm_ticks += 1
        # established slots kept decoding under the admission
        assert [len(r.generated) for r in est] == [n + 1 for n in n_est]
    assert warm_ticks == 11                       # 12 chunks, 1 on admission
    assert len(newcomer.generated) == 2           # decoded on the last tick
    assert [len(r.generated) for r in est] == \
        [n + 12 for n in est_before]
    outs = sched.run()
    assert len(outs[newcomer.rid]) == 6


def test_cancel_during_prefilling_frees_slot_and_drops_ticket(setup):
    """Satellite: cancel(rid) mid-warm must free the slot, drop the
    ticket (no further chunks replay), and emit exactly one terminal
    (rid, -1, True) event."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1, capacity=96, prefill_chunk=4,
                  admit_chunks_per_tick=1)
    sched = ContinuousBatchingScheduler(eng)
    rng = np.random.default_rng(13)
    victim = sched.submit(rng.integers(0, cfg.vocab_size, 40),
                          max_new_tokens=8)
    waiting = sched.submit(rng.integers(0, cfg.vocab_size, 6),
                           max_new_tokens=3)
    sched.step()                                  # admit + 1 of 10 chunks
    assert sched.prefill_pending == 1
    chunks_at_cancel = eng.stats.prefill_chunks

    assert sched.cancel(victim.rid)
    assert sched.prefill_pending == 0             # ticket dropped
    assert sched.num_active == 0                  # slot freed immediately
    finished, events = sched._tick()
    assert events[0] == (victim.rid, -1, True)
    assert victim in finished
    ev_victim = [e for e in events if e[0] == victim.rid]
    assert ev_victim == [(victim.rid, -1, True)]  # exactly one terminal
    # the freed slot admitted the waiting request on that same tick; the
    # victim's remaining 9 chunks never replayed (only the waiter's 2)
    assert any(s is not None and s.rid == waiting.rid for s in sched.slots)
    outs = sched.run()
    assert len(outs[waiting.rid]) == 3
    assert len(outs[victim.rid]) == 1             # the prefill token only
    assert eng.stats.prefill_chunks == chunks_at_cancel + 2
    # cancelling again is a no-op
    assert not sched.cancel(victim.rid)


# ---------------------------------------------------------------------------
# bounded admission + pause/resume (backpressure)
# ---------------------------------------------------------------------------

def test_bounded_queue_rejects_and_blocks(setup):
    """max_queue bounds the waiting line: block=False raises the typed
    QueueFull (counted in queue_rejected); the blocking default drives
    ticks until space frees and then queues the request."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng, max_queue=1)
    prompts = _prompts(cfg, 4, seed=21)
    r0 = sched.submit(prompts[0], max_new_tokens=2)
    sched.step()                                  # r0 into the slot
    r1 = sched.submit(prompts[1], max_new_tokens=2)   # fills the queue
    with pytest.raises(QueueFull, match="max_queue"):
        sched.submit(prompts[2], max_new_tokens=2, block=False)
    assert sched.stats.queue_rejected == 1
    assert sched.stats.requests_submitted == 2    # rejected never queued
    r3 = sched.submit(prompts[3], max_new_tokens=2)   # blocks, then queues
    outs = sched.run()
    assert sorted(outs) == [r0.rid, r1.rid, r3.rid]
    for r in (r0, r1, r3):
        assert len(outs[r.rid]) == 2
    with pytest.raises(ValueError, match="max_queue"):
        ContinuousBatchingScheduler(eng, max_queue=0)


def test_blocking_submit_preserves_stream_events(setup):
    """Regression: ticks driven INSIDE a blocking submit() must not drop
    their stream events — a request that fully decodes while a producer
    is blocked still delivers every token and its terminal done=True
    through the next stream()."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng, max_queue=1)
    prompts = _prompts(cfg, 3, seed=23)
    r0 = sched.submit(prompts[0], max_new_tokens=3)
    sched.step()                          # r0 into the slot, 2 of 3 tokens
    #                                       (their events consumed by step)
    assert not r0.done
    r1 = sched.submit(prompts[1], max_new_tokens=2)
    r2 = sched.submit(prompts[2], max_new_tokens=2)  # blocks; r0 finishes
    assert r0.done                        # decoded during the blocked submit
    events = list(sched.stream())
    by_rid = {}
    for rid, tok, done in events:
        by_rid.setdefault(rid, []).append((tok, done))
    # r0's remaining token + done=True survived the blocking submit
    assert [d for _, d in by_rid[r0.rid]] == [True]
    for r in (r1, r2):
        assert [d for _, d in by_rid[r.rid]] == [False, True]
        assert [t for t, _ in by_rid[r.rid]] == list(r.output)


def test_pause_resume_admission(setup):
    """pause_admission() holds queued requests (stream() drains only the
    in-flight work, admission_stalls count the waiting ticks); resume
    serves them; a paused full queue raises QueueFull even when
    blocking."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=2)
    sched = ContinuousBatchingScheduler(eng, max_queue=2)
    prompts = _prompts(cfg, 4, seed=22)
    r0 = sched.submit(prompts[0], max_new_tokens=3)
    sched.step()
    sched.pause_admission()
    assert sched.admission_paused
    r1 = sched.submit(prompts[1], max_new_tokens=3)
    outs = sched.run()                        # drains r0 only
    assert list(outs) == [r0.rid]
    assert sched.stats.requests_queued == 1
    assert sched.stats.admission_stalls > 0
    r2 = sched.submit(prompts[2], max_new_tokens=3)   # queue now full
    with pytest.raises(QueueFull, match="paused"):
        sched.submit(prompts[3], max_new_tokens=3)    # blocking can't drain
    sched.resume_admission()
    assert not sched.admission_paused
    outs = sched.run()
    assert sorted(outs) == [r0.rid, r1.rid, r2.rid]
    for rid in (r1.rid, r2.rid):
        assert len(outs[rid]) == 3


def test_staggered_positions_decode_correctly(setup):
    """Slots at different KV positions (different prompt lengths) coexist:
    the scheduler output for each request equals its solo scheduler run."""
    cfg, params = setup
    prompts = [np.arange(4, dtype=np.int32), np.arange(9, dtype=np.int32),
               np.arange(6, dtype=np.int32)]
    solo = []
    for p in prompts:
        eng1 = _engine(cfg, params, slots=1)
        s1 = ContinuousBatchingScheduler(eng1)
        r = s1.submit(p, max_new_tokens=4)
        solo.append(s1.run()[r.rid])
    eng = _engine(cfg, params, slots=3)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=4) for p in prompts]
    outs = sched.run()
    for r, s in zip(reqs, solo):
        # first token comes from the (batch-independent) prefill: exact.
        assert outs[r.rid][0] == s[0]
        assert len(outs[r.rid]) == len(s)
