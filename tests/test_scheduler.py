"""Continuous-batching scheduler + batched engine behaviour tests.

The acceptance bar for the batched serving core: >=4 concurrent requests
decode through ONE shared expert cache in one padded step; padded slots
are bitwise-invisible to active rows; a batched step computes the same
logits as independent single-request decodes (bf16 tolerance); slots
recycle so more requests than slots drain to completion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, ContinuousBatchingScheduler, \
    EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _engine(cfg, params, slots=4, capacity=64):
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    return CollaborativeEngine(
        cfg, params, EngineConfig(cache=ccfg, max_batch=slots,
                                  capacity=capacity),
        key=jax.random.PRNGKey(3))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9)))
            .astype(np.int32) for _ in range(n)]


def test_four_concurrent_requests_share_one_cache(setup):
    """>=4 requests in flight simultaneously, one shared expert cache."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=4)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=6) for p in _prompts(cfg, 4)]
    sched.step()
    assert sched.num_active == 4                  # all four decode together
    outs = sched.run()
    assert sorted(outs) == [r.rid for r in reqs]
    for r in reqs:
        assert len(outs[r.rid]) == 6
    stats = sched.stats
    # every decode step served the full batch through the one cache
    assert stats.accesses == stats.hits + stats.host_assignments
    assert stats.tokens == 4 * 5                  # 5 decode ticks per request
    assert 0.0 <= stats.hit_rate <= 1.0
    assert stats.requests_submitted == stats.requests_finished == 4


def test_slots_recycle_when_requests_outnumber_slots(setup):
    cfg, params = setup
    eng = _engine(cfg, params, slots=2)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=3 + i)
            for i, p in enumerate(_prompts(cfg, 5, seed=1))]
    outs = sched.run()
    assert len(outs) == 5
    for i, r in enumerate(reqs):
        assert len(outs[r.rid]) == 3 + i
    # with 2 slots, 5 requests were never all in flight, yet all completed
    assert sched.queue == type(sched.queue)()


def test_padded_slots_are_bitwise_invisible(setup):
    """Garbage in inactive slots (tokens, KV positions) must not change
    active rows' logits AT ALL — the isolation that makes continuous
    batching correct."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=4)
    prompt = _prompts(cfg, 1)[0]
    tok, one_state = eng.prefill_request(prompt)

    def run(junk_tok, junk_pos):
        state = eng.init_slots()
        state = eng.write_slot(state, one_state, 0)
        state["pos"] = state["pos"].at[1:].set(junk_pos)
        fast0 = eng.fast                               # snapshot tiers
        tokens = np.full((4, 1), junk_tok, np.int32)
        tokens[0, 0] = tok
        active = np.array([True, False, False, False])
        logits, _, fast, stats = eng._decode(
            jnp.asarray(tokens), state, fast0, jnp.asarray(active))
        return (np.asarray(logits[0, 0]), jax.tree.map(np.asarray, fast),
                {k: int(np.asarray(v).sum()) for k, v in stats.items()})

    # donation invalidates eng.fast: rebuild the engine per variant
    l1, f1, s1 = run(junk_tok=7, junk_pos=0)
    eng = _engine(cfg, params, slots=4)
    tok2, one_state = eng.prefill_request(prompt)
    assert tok2 == tok
    l2, f2, s2 = run(junk_tok=301, junk_pos=13)
    np.testing.assert_array_equal(l1, l2)
    jax.tree.map(np.testing.assert_array_equal, f1, f2)
    assert s1 == s2
    assert s1["accesses"] == cfg.num_layers * cfg.moe.top_k  # active row only


def test_batched_step_matches_single_request_logits(setup):
    """One padded 4-way decode step == four independent 1-way decode steps
    (same KV state, same cache-off... identical weights), row by row,
    within bf16 tolerance. Verifies no cross-slot leakage through
    attention, routing or the grouped MoE dispatch."""
    cfg, params = setup
    prompts = _prompts(cfg, 4, seed=2)

    # batched: prefill each request into its slot, one decode step
    eng = _engine(cfg, params, slots=4)
    state = eng.init_slots()
    next_tok = np.zeros((4, 1), np.int32)
    for t, p in enumerate(prompts):
        tok, one_state = eng.prefill_request(p)
        state = eng.write_slot(state, one_state, t)
        next_tok[t, 0] = tok
    logits_b, _ = eng.decode_batch(next_tok, state, np.ones(4, bool))
    logits_b = np.asarray(logits_b[:, 0], np.float32)

    # solo: same step for each request alone
    for t, p in enumerate(prompts):
        eng1 = _engine(cfg, params, slots=1)
        tok, one_state = eng1.prefill_request(p)
        assert tok == next_tok[t, 0]
        state1 = eng1.init_slots()
        state1 = eng1.write_slot(state1, one_state, 0)
        logits_s, _ = eng1.decode_batch(np.asarray([[tok]], np.int32),
                                        state1, np.ones(1, bool))
        np.testing.assert_allclose(
            logits_b[t], np.asarray(logits_s[0, 0], np.float32),
            rtol=2e-2, atol=2e-2)


def test_cancel_mid_decode_frees_slot_and_admits_waiting(setup):
    """cancel(rid) mid-decode: the request stops decoding immediately, a
    terminal (rid, token, done=True) event is emitted, and the freed slot
    admits a waiting request on the next tick."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=2)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=8)
            for p in _prompts(cfg, 3, seed=3)]
    sched.step()                                  # r0, r1 in flight
    assert sched.num_active == 2 and len(sched.queue) == 1
    victim = reqs[0]
    n_before = len(victim.generated)
    assert sched.cancel(victim.rid)
    assert victim.done and victim.cancelled
    # slot freed immediately; no further tokens for the cancelled request
    assert sched.num_active == 1
    finished, events = sched._tick()
    # -1 sentinel: every real token was already streamed exactly once
    assert events[0] == (victim.rid, -1, True)
    assert victim in finished                     # step() reports it done
    assert len(victim.generated) == n_before      # token stream rejected
    # the waiting request took the freed slot on that same tick
    assert sched.num_active == 2
    assert any(s is not None and s.rid == reqs[2].rid for s in sched.slots)
    outs = sched.run()
    assert sorted(outs) == [r.rid for r in reqs]
    assert len(outs[victim.rid]) == n_before < 8
    for r in (reqs[1], reqs[2]):
        assert len(outs[r.rid]) == 8
    # cancelling again (or an unknown rid) is a no-op, not an error
    assert not sched.cancel(victim.rid)
    assert not sched.cancel(10_000)


def test_cancel_from_on_token_callback_at_admission(setup):
    """An on_token handler that cancels its own request on the FIRST
    token (content-filter style) must take effect: the request is live in
    its slot when the callback fires, so cancel() frees it and no decode
    tokens follow."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit(
        _prompts(cfg, 1, seed=7)[0], max_new_tokens=8,
        on_token=lambda tok, done: done or sched.cancel(req.rid))
    outs = sched.run()
    assert req.cancelled
    assert len(outs[req.rid]) == 1                # the prefill token only
    assert sched.stats.requests_finished == 1


def test_cancel_finished_request_awaiting_retirement_is_noop(setup):
    """A request that finished on the last tick but still occupies its
    slot (retirement happens at the next tick's start) already streamed
    its terminal event — cancel() must refuse rather than emit a second
    done=True."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit(_prompts(cfg, 1, seed=6)[0], max_new_tokens=2)
    sched.step()                          # admit + first decode -> done
    assert req.done and sched.slots[0] is req
    assert not sched.cancel(req.rid)
    assert not sched._cancel_events
    assert not req.cancelled
    sched.step()                          # normal retirement
    assert sched.finished == [req]


def test_cancel_queued_request_and_stream_terminal_event(setup):
    """A queued request cancels without ever decoding: stream() delivers
    exactly one event for it — (rid, -1, done=True) — and on_token fires
    once with done=True."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=1)
    sched = ContinuousBatchingScheduler(eng)
    seen = []
    r0 = sched.submit(_prompts(cfg, 1, seed=4)[0], max_new_tokens=3)
    rq = sched.submit(_prompts(cfg, 1, seed=5)[0], max_new_tokens=3,
                      on_token=lambda tok, done: seen.append((tok, done)))
    assert sched.cancel(rq.rid)                   # still queued: no tokens
    assert seen == [(-1, True)]
    events = list(sched.stream())
    ev_rq = [e for e in events if e[0] == rq.rid]
    assert ev_rq == [(rq.rid, -1, True)]
    done_flags = [e for e in events if e[0] == r0.rid]
    assert len(done_flags) == 3 and done_flags[-1][2]
    assert sorted(r.rid for r in sched.finished) == [r0.rid, rq.rid]
    assert rq.output.size == 0


def test_staggered_positions_decode_correctly(setup):
    """Slots at different KV positions (different prompt lengths) coexist:
    the scheduler output for each request equals its solo scheduler run."""
    cfg, params = setup
    prompts = [np.arange(4, dtype=np.int32), np.arange(9, dtype=np.int32),
               np.arange(6, dtype=np.int32)]
    solo = []
    for p in prompts:
        eng1 = _engine(cfg, params, slots=1)
        s1 = ContinuousBatchingScheduler(eng1)
        r = s1.submit(p, max_new_tokens=4)
        solo.append(s1.run()[r.rid])
    eng = _engine(cfg, params, slots=3)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=4) for p in prompts]
    outs = sched.run()
    for r, s in zip(reqs, solo):
        # first token comes from the (batch-independent) prefill: exact.
        assert outs[r.rid][0] == s[0]
        assert len(outs[r.rid]) == len(s)
