"""Benchmark JSON artifact schema: the ``--json`` outputs are validated
against ``RunStats.to_json()`` / ``EngineStats.to_json()``.

Pins two contracts: (a) typed stats export only JSON-native types and
round-trip through ``json.dumps``/``json.loads`` exactly (the old
string-keyed dict mixed a numpy array into the scalar channel and made
``json.dumps`` raise), and (b) ``benchmarks.common.dump_json`` writes the
``{"results": [...], "runs": [...]}`` schema CI archives, with every run
entry shaped like a typed-stats export.
"""
import copy
import importlib
import json
import pathlib
import pickle
import sys

import pytest

from repro.serving import EngineStats, RunStats

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
common = importlib.import_module("benchmarks.common")

SAMPLE = EngineStats(hits=7, accesses=12, host_assignments=5,
                     fetched_experts=3, tokens=6, steps=3,
                     prefetch_issued=4, prefetch_hits=2, prefetch_wasted=1,
                     predicted=8, predicted_correct=6,
                     prefill_hits=9, prefill_accesses=20, prefill_fetched=4,
                     prefill_tokens=10, prefill_chunks=2, first_tokens=2,
                     prefill_segments=3, prefix_tokens_skipped=4,
                     cpu_expert_calls=2, cpu_tokens=3, miss_expert_groups=3,
                     fused_groups=2, census_calls=2, census_threads=7,
                     affinity_hits=1, host_busy_us=150, host_queue_peak=2,
                     kv_pages_in_use=5, prefix_hits=1,
                     cow_forks=1, prefix_pages_retained=2,
                     per_layer_hits=(3, 4), per_layer_accesses=(6, 6))

ENGINE_KEYS = {
    "hits", "accesses", "host_assignments", "fetched_experts", "tokens",
    "steps", "prefetch_issued", "prefetch_hits", "prefetch_wasted",
    "predicted", "predicted_correct", "prefill_hits", "prefill_accesses",
    "prefill_fetched", "prefill_tokens", "prefill_chunks", "first_tokens",
    "prefill_segments", "prefix_tokens_skipped", "generated_tokens",
    "cpu_expert_calls", "cpu_tokens", "miss_expert_groups",
    "fused_groups", "census_calls", "census_threads", "affinity_hits",
    "host_busy_us", "host_queue_peak",
    "kv_pages_in_use", "prefix_hits", "cow_forks",
    "prefix_pages_retained",
    "hit_rate", "prefetch_hit_rate", "prefetch_waste_rate",
    "prediction_accuracy", "prefill_hit_rate", "cpu_offload_rate",
    "per_layer_hits", "per_layer_accesses", "per_layer_hit_rates",
}
RUN_KEYS = {"requests_submitted", "requests_finished", "requests_active",
            "requests_queued", "prefill_pending", "admission_stalls",
            "queue_rejected",
            "ttft_ms_p50", "ttft_ms_p95", "ttft_ms_p99",
            "tpot_ms_p50", "tpot_ms_p95", "tpot_ms_p99",
            "stall_ms_p50", "stall_ms_p95", "stall_ms_p99",
            "engine"}


def test_engine_stats_json_round_trips():
    d = SAMPLE.to_json()
    assert set(d) == ENGINE_KEYS
    assert json.loads(json.dumps(d)) == d        # exact round-trip
    for k, v in d.items():
        assert isinstance(v, (int, float, list)), (k, type(v))
    assert d["hit_rate"] == pytest.approx(7 / 12)
    assert d["per_layer_hit_rates"] == [0.5, 4 / 6]
    assert d["prefill_hit_rate"] == pytest.approx(9 / 20)
    assert d["cpu_offload_rate"] == pytest.approx(3 / 5)
    # first tokens fold into reported totals (tokens stays decode-only)
    assert d["generated_tokens"] == d["tokens"] + d["first_tokens"] == 8


def test_run_stats_delegate_and_round_trip():
    rs = RunStats(engine=SAMPLE, requests_submitted=3, requests_finished=2,
                  requests_active=1, requests_queued=0)
    # engine counters and rates reachable without the .engine hop
    assert rs.hits == 7 and rs.hit_rate == pytest.approx(7 / 12)
    d = rs.to_json()
    assert set(d) == RUN_KEYS
    assert set(d["engine"]) == ENGINE_KEYS
    assert json.loads(json.dumps(d)) == d


def test_run_stats_survive_copy_and_pickle():
    """Regression: the delegating __getattr__ used to recurse infinitely
    on instances whose fields are not set yet (copy.copy / pickle
    reconstruct via __new__ before filling the dict, then probe
    attributes) — "engine" itself must raise a plain AttributeError
    instead of delegating to self.engine."""
    rs = RunStats(engine=SAMPLE, requests_submitted=3, requests_finished=2,
                  prefill_pending=1, admission_stalls=4, queue_rejected=1)
    for clone in (copy.copy(rs), copy.deepcopy(rs),
                  pickle.loads(pickle.dumps(rs))):
        assert clone.requests_submitted == 3
        assert clone.engine == SAMPLE
        assert clone.hits == 7                     # delegation still works
        assert clone.hit_rate == pytest.approx(7 / 12)
        assert clone.admission_stalls == 4
        assert clone.to_json() == rs.to_json()
    # a half-built instance raises AttributeError (not RecursionError)
    empty = object.__new__(RunStats)
    with pytest.raises(AttributeError):
        empty.engine
    with pytest.raises(AttributeError):
        empty.hits


def test_zero_guarded_rates_on_empty_stats():
    """A run that never decoded reports 0.0 rates, not ZeroDivisionError."""
    s = EngineStats()
    assert s.hit_rate == s.prefetch_hit_rate == 0.0
    assert s.prediction_accuracy == s.prefetch_waste_rate == 0.0
    assert s.prefill_hit_rate == 0.0
    assert s.cpu_offload_rate == 0.0
    assert s.per_layer_hit_rates.shape == (0,)
    json.dumps(RunStats().to_json())


def test_dump_json_schema(tmp_path, monkeypatch):
    """dump_json writes {"results", "runs"} with run entries validating
    against the RunStats.to_json() schema."""
    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    common.emit("bench.micro", 12.5, "derived=1")
    common.record_run("bench.run",
                      RunStats(engine=SAMPLE, requests_submitted=2,
                               requests_finished=2))
    path = tmp_path / "BENCH_test.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())

    assert set(doc) == {"results", "runs"}
    assert doc["results"] == [
        {"name": "bench.micro", "us": 12.5, "derived": "derived=1"}]
    (run,) = doc["runs"]
    assert run["name"] == "bench.run"
    assert set(run["stats"]) == RUN_KEYS
    assert set(run["stats"]["engine"]) == ENGINE_KEYS
    # EngineStats exports (decode_prefetch's generate() path) validate too
    common.record_run("bench.engine_only", SAMPLE)
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    assert set(doc["runs"][1]["stats"]) == ENGINE_KEYS


def test_live_fleet_artifact_shapes(tmp_path, monkeypatch):
    """BENCH_fig5_throughput.json / BENCH_fig6_hitrate.json: the live-mode
    sweeps (fig5_throughput's concurrency scaling, fig6_hitrate's policy/
    prefetch matrix) record RunStats payloads that validate against the
    pinned schema like every other benchmark artifact."""
    importlib.import_module("benchmarks.fig5_throughput")
    importlib.import_module("benchmarks.fig6_hitrate")
    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    names = ["fig5.live.slots1", "fig5.live.slots4",
             "fig6.live.lru.pf", "fig6.live.lfu"]
    for name in names:
        common.record_run(name, RunStats(engine=SAMPLE,
                                         requests_submitted=4,
                                         requests_finished=4))
    path = tmp_path / "BENCH_fig5_throughput.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    assert [r["name"] for r in doc["runs"]] == names
    for run in doc["runs"]:
        assert set(run["stats"]) == RUN_KEYS
        assert set(run["stats"]["engine"]) == ENGINE_KEYS


def test_admission_overlap_artifact_shape(tmp_path, monkeypatch):
    """BENCH_admission_overlap.json: the CI smoke artifact triples an
    off/on/seg run whose stats carry the overlapped-admission channel
    (prefill_pending / admission_stalls / queue_rejected on the run,
    first_tokens / generated_tokens / prefill_segments /
    prefix_tokens_skipped on the engine) next to the established-latency
    and prefix-TTFT results."""
    bench = importlib.import_module("benchmarks.admission_overlap")
    assert [m[0] for m in bench.MODES] == ["off", "on", "seg"]
    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    names = ["admission_overlap.off", "admission_overlap.on",
             "admission_overlap.seg", "admission_overlap.prefix"]
    for name in names:
        common.emit(f"{name}.stall", 1234.5, "max established gap")
        common.record_run(name, RunStats(engine=SAMPLE,
                                         requests_submitted=3,
                                         requests_finished=3,
                                         admission_stalls=2))
    common.emit("admission_overlap.prefix_ttft.cold", 9000.0, "cold TTFT")
    common.emit("admission_overlap.prefix_ttft.hit", 4000.0, "hit TTFT")
    path = tmp_path / "BENCH_admission_overlap.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    assert [r["name"] for r in doc["runs"]] == names
    for run in doc["runs"]:
        stats = run["stats"]
        assert set(stats) == RUN_KEYS
        assert {"prefill_pending", "admission_stalls",
                "queue_rejected"} <= set(stats)
        assert set(stats["engine"]) == ENGINE_KEYS
        assert {"prefill_segments", "prefix_tokens_skipped",
                "prefix_pages_retained"} <= set(stats["engine"])
        assert stats["engine"]["generated_tokens"] == \
            stats["engine"]["tokens"] + stats["engine"]["first_tokens"]


def test_paged_kv_artifact_shape(tmp_path, monkeypatch):
    """BENCH_paged_kv.json: the CI smoke artifact pairs a dense/paged run
    whose engine stats carry the paged-KV channel (kv_pages_in_use /
    prefix_hits / cow_forks) next to the page-occupancy and TTFT
    results."""
    importlib.import_module("benchmarks.paged_kv")          # importable
    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    common.emit("paged_kv.peak_pages", 17.0, "paged fleet peak occupancy")
    common.emit("paged_kv.ttft_prefix_hit_us", 11400.0, "warm-skip TTFT")
    for name in ("paged_kv.dense", "paged_kv.paged"):
        common.record_run(name, RunStats(engine=SAMPLE,
                                         requests_submitted=6,
                                         requests_finished=6))
    path = tmp_path / "BENCH_paged_kv.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    assert [r["name"] for r in doc["runs"]] == ["paged_kv.dense",
                                                "paged_kv.paged"]
    for run in doc["runs"]:
        eng = run["stats"]["engine"]
        assert set(eng) == ENGINE_KEYS
        assert {"kv_pages_in_use", "prefix_hits",
                "cow_forks", "fused_groups"} <= set(eng)


def test_host_compute_artifact_shape_and_cost_model(tmp_path, monkeypatch):
    """BENCH_host_compute.json: the CI smoke artifact carries the
    host-execution channel in every run entry, and the benchmark's
    miss-handling cost model obeys the dispatcher's decision rule (the
    self-check's foundation): per-group savings are positive exactly when
    the policy prefers the CPU."""
    host_compute = importlib.import_module("benchmarks.host_compute")
    from repro.core.costmodel import MIXTRAL_TIMINGS
    from repro.hostexec import HostDispatchPolicy

    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    common.record_run("host_compute.off", SAMPLE)
    common.record_run("host_compute.on", SAMPLE)
    path = tmp_path / "BENCH_host_compute.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    assert [r["name"] for r in doc["runs"]] == ["host_compute.off",
                                                "host_compute.on"]
    for run in doc["runs"]:
        stats = run["stats"]
        assert set(stats) == ENGINE_KEYS
        assert {"cpu_expert_calls", "cpu_tokens",
                "cpu_offload_rate"} <= set(stats)

    # SAMPLE dispatched 2 one-plus-token groups at 8 threads (CPU-favored
    # on the paper's Mixtral timings): the modeled miss handling drops
    pol = HostDispatchPolicy(MIXTRAL_TIMINGS, threads=8)
    assert pol.prefers_cpu(1)
    ms_off, ms_on = host_compute.miss_handling_ms(SAMPLE, pol)
    assert ms_on < ms_off
    # one thread: the cost model prefers the fetch, and a run that
    # dispatched nothing to the CPU models no reduction
    none = EngineStats(hits=7, accesses=12, host_assignments=5,
                       fetched_experts=3, steps=3)
    ms_off0, ms_on0 = host_compute.miss_handling_ms(
        none, HostDispatchPolicy(MIXTRAL_TIMINGS, threads=1))
    assert ms_on0 == ms_off0


def test_obs_overhead_artifact_shape(tmp_path, monkeypatch):
    """BENCH_obs_overhead.json: the tracing-overhead artifact records a
    RunStats whose latency-percentile channel (ttft_ms_* / tpot_ms_* /
    stall_ms_*) is part of the pinned run schema, next to the traced /
    untraced tok/s and overhead_pct results."""
    importlib.import_module("benchmarks.obs_overhead")      # importable
    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    common.emit("obs_overhead.tok_s.untraced", 120.0, "median tok/s")
    common.emit("obs_overhead.tok_s.traced", 118.0, "median tok/s")
    common.emit("obs_overhead.overhead_pct", 1.7, "bound 5%")
    common.record_run("obs_overhead.traced",
                      RunStats(engine=SAMPLE, requests_submitted=5,
                               requests_finished=5, ttft_ms_p50=12.5,
                               ttft_ms_p99=20.0, tpot_ms_p50=3.0,
                               tpot_ms_p99=6.5, stall_ms_p50=0.4,
                               stall_ms_p99=2.0))
    path = tmp_path / "BENCH_obs_overhead.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    (run,) = doc["runs"]
    assert run["name"] == "obs_overhead.traced"
    stats = run["stats"]
    assert set(stats) == RUN_KEYS
    assert {"ttft_ms_p50", "ttft_ms_p95", "ttft_ms_p99",
            "tpot_ms_p50", "tpot_ms_p95", "tpot_ms_p99",
            "stall_ms_p50", "stall_ms_p95", "stall_ms_p99"} <= set(stats)
    assert stats["ttft_ms_p50"] == pytest.approx(12.5)
    assert stats["tpot_ms_p95"] == 0.0          # unset percentiles default
    assert set(stats["engine"]) == ENGINE_KEYS
    # the executor pool-utilization channel rides in the engine export
    assert {"host_busy_us", "host_queue_peak"} <= set(stats["engine"])


# -- reprolint CI artifacts: REPROLINT.json / REPROLINT.sarif ----------------

REPROLINT_FIXTURE = (pathlib.Path(__file__).resolve().parent
                     / "analysis_fixtures" / "rl011_bad")
FINDING_KEYS = {"rule", "file", "line", "message", "symbol", "severity"}


def test_reprolint_json_artifact_schema(tmp_path, capsys):
    """REPROLINT.json: {"new", "grandfathered", "stale_baseline"} with each
    finding dict carrying location, identity, and severity — the shape the
    CI failure annotations parse."""
    from repro.analysis.cli import main as reprolint

    out = tmp_path / "REPROLINT.json"
    assert reprolint(["--root", str(REPROLINT_FIXTURE), "--rules", "RL011",
                      "--json", str(out)]) == 1
    capsys.readouterr()
    doc = json.loads(out.read_text())
    assert set(doc) == {"new", "grandfathered", "stale_baseline"}
    assert doc["grandfathered"] == [] and doc["stale_baseline"] == []
    assert len(doc["new"]) == 2
    for f in doc["new"]:
        assert set(f) == FINDING_KEYS
        assert f["rule"] == "RL011" and f["severity"] == "warning"
        assert isinstance(f["line"], int) and f["line"] > 0
        assert f["file"].startswith("src/repro/")
    assert json.loads(json.dumps(doc)) == doc


def test_reprolint_sarif_artifact_schema(tmp_path, capsys):
    """REPROLINT.sarif: minimal valid SARIF 2.1.0 — versioned log, one run,
    a rule descriptor per registered rule, results indexing into them with
    the baseline's line-number-free key as the fingerprint."""
    from repro.analysis.cli import main as reprolint
    from repro.analysis.core import RULES
    from repro.analysis.sarif import SARIF_SCHEMA

    out = tmp_path / "REPROLINT.sarif"
    assert reprolint(["--root", str(REPROLINT_FIXTURE), "--rules", "RL011",
                      "--sarif", str(out)]) == 1
    capsys.readouterr()
    log = json.loads(out.read_text())
    assert set(log) == {"$schema", "version", "runs"}
    assert log["$schema"] == SARIF_SCHEMA
    assert log["version"] == "2.1.0"
    (run,) = log["runs"]
    driver = run["tool"]["driver"]
    assert driver["name"] == "reprolint"
    assert [r["id"] for r in driver["rules"]] == sorted(RULES)
    for r in driver["rules"]:
        assert set(r) == {"id", "shortDescription", "defaultConfiguration"}
        assert r["defaultConfiguration"]["level"] in ("error", "warning",
                                                      "note")
    assert len(run["results"]) == 2
    for res in run["results"]:
        assert set(res) == {"ruleId", "ruleIndex", "level", "message",
                            "locations", "partialFingerprints"}
        assert driver["rules"][res["ruleIndex"]]["id"] == res["ruleId"]
        (loc,) = res["locations"]
        region = loc["physicalLocation"]["region"]
        assert region["startLine"] > 0
        key = res["partialFingerprints"]["reprolintKey/v1"].split("\t")
        assert key[0] == res["ruleId"]
        assert key[1] == loc["physicalLocation"]["artifactLocation"]["uri"]


def test_reprolint_baseline_is_byte_stable(tmp_path):
    """--update-baseline determinism: shuffled, duplicated findings with
    control characters in messages serialize to identical bytes, and the
    sanitized keys still match on re-read."""
    from repro.analysis.baseline import (load_baseline, save_baseline,
                                         split_findings)
    from repro.analysis.core import Finding

    def mk(rule, file, line, msg, sym):
        return Finding(rule=rule, file=file, line=line, message=msg,
                       symbol=sym)

    findings = [
        mk("RL008", "src/repro/a.py", 10, "leak\ton a\npath", "A.f"),
        mk("RL009", "src/repro/b.py", 20, "unlocked write", "B"),
        mk("RL008", "src/repro/a.py", 99, "leak\ton a\npath", "A.f"),
    ]  # third is a line-moved duplicate of the first: same identity
    p1, p2 = tmp_path / "b1", tmp_path / "b2"
    save_baseline(p1, findings)
    save_baseline(p2, list(reversed(findings)))
    assert p1.read_bytes() == p2.read_bytes()

    baseline = load_baseline(p1)
    assert len(baseline) == 2                    # deduped, sanitized
    assert all("\t" not in part and "\n" not in part
               for key in baseline for part in key)
    new, old, stale = split_findings(findings, baseline)
    assert new == [] and stale == []             # control chars still match
    assert len(old) == 3
