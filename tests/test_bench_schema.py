"""Benchmark JSON artifact schema: the ``--json`` outputs are validated
against ``RunStats.to_json()`` / ``EngineStats.to_json()``.

Pins two contracts: (a) typed stats export only JSON-native types and
round-trip through ``json.dumps``/``json.loads`` exactly (the old
string-keyed dict mixed a numpy array into the scalar channel and made
``json.dumps`` raise), and (b) ``benchmarks.common.dump_json`` writes the
``{"results": [...], "runs": [...]}`` schema CI archives, with every run
entry shaped like a typed-stats export.
"""
import importlib
import json
import pathlib
import sys

import pytest

from repro.serving import EngineStats, RunStats

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
common = importlib.import_module("benchmarks.common")

SAMPLE = EngineStats(hits=7, accesses=12, host_assignments=5,
                     fetched_experts=3, tokens=6, steps=3,
                     prefetch_issued=4, prefetch_hits=2, prefetch_wasted=1,
                     predicted=8, predicted_correct=6,
                     prefill_hits=9, prefill_accesses=20, prefill_fetched=4,
                     prefill_tokens=10, prefill_chunks=2,
                     per_layer_hits=(3, 4), per_layer_accesses=(6, 6))

ENGINE_KEYS = {
    "hits", "accesses", "host_assignments", "fetched_experts", "tokens",
    "steps", "prefetch_issued", "prefetch_hits", "prefetch_wasted",
    "predicted", "predicted_correct", "prefill_hits", "prefill_accesses",
    "prefill_fetched", "prefill_tokens", "prefill_chunks",
    "hit_rate", "prefetch_hit_rate", "prefetch_waste_rate",
    "prediction_accuracy", "prefill_hit_rate",
    "per_layer_hits", "per_layer_accesses", "per_layer_hit_rates",
}
RUN_KEYS = {"requests_submitted", "requests_finished", "requests_active",
            "requests_queued", "engine"}


def test_engine_stats_json_round_trips():
    d = SAMPLE.to_json()
    assert set(d) == ENGINE_KEYS
    assert json.loads(json.dumps(d)) == d        # exact round-trip
    for k, v in d.items():
        assert isinstance(v, (int, float, list)), (k, type(v))
    assert d["hit_rate"] == pytest.approx(7 / 12)
    assert d["per_layer_hit_rates"] == [0.5, 4 / 6]
    assert d["prefill_hit_rate"] == pytest.approx(9 / 20)


def test_run_stats_delegate_and_round_trip():
    rs = RunStats(engine=SAMPLE, requests_submitted=3, requests_finished=2,
                  requests_active=1, requests_queued=0)
    # engine counters and rates reachable without the .engine hop
    assert rs.hits == 7 and rs.hit_rate == pytest.approx(7 / 12)
    d = rs.to_json()
    assert set(d) == RUN_KEYS
    assert set(d["engine"]) == ENGINE_KEYS
    assert json.loads(json.dumps(d)) == d


def test_zero_guarded_rates_on_empty_stats():
    """A run that never decoded reports 0.0 rates, not ZeroDivisionError."""
    s = EngineStats()
    assert s.hit_rate == s.prefetch_hit_rate == 0.0
    assert s.prediction_accuracy == s.prefetch_waste_rate == 0.0
    assert s.prefill_hit_rate == 0.0
    assert s.per_layer_hit_rates.shape == (0,)
    json.dumps(RunStats().to_json())


def test_dump_json_schema(tmp_path, monkeypatch):
    """dump_json writes {"results", "runs"} with run entries validating
    against the RunStats.to_json() schema."""
    monkeypatch.setattr(common, "_RESULTS", [])
    monkeypatch.setattr(common, "_RUNS", [])
    common.emit("bench.micro", 12.5, "derived=1")
    common.record_run("bench.run",
                      RunStats(engine=SAMPLE, requests_submitted=2,
                               requests_finished=2))
    path = tmp_path / "BENCH_test.json"
    common.dump_json(str(path))
    doc = json.loads(path.read_text())

    assert set(doc) == {"results", "runs"}
    assert doc["results"] == [
        {"name": "bench.micro", "us": 12.5, "derived": "derived=1"}]
    (run,) = doc["runs"]
    assert run["name"] == "bench.run"
    assert set(run["stats"]) == RUN_KEYS
    assert set(run["stats"]["engine"]) == ENGINE_KEYS
    # EngineStats exports (decode_prefetch's generate() path) validate too
    common.record_run("bench.engine_only", SAMPLE)
    common.dump_json(str(path))
    doc = json.loads(path.read_text())
    assert set(doc["runs"][1]["stats"]) == ENGINE_KEYS
