"""Paged-KV serving: the engine/scheduler acceptance bar for the page
pool.

The paged path must be bitwise-invisible in the tokens (same scheduler
run, dense vs paged KV), share prompt-prefix pages across admissions,
backpressure admission on pool pages (FIFO, no starvation, no deadlock
mid-decode), fork live requests copy-on-write, and guard the dense-only
engine entry points with clear errors.
"""
import jax
import numpy as np
import pytest

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, ContinuousBatchingScheduler, \
    EngineConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params


def _engine(cfg, params, slots=4, capacity=64, **ecfg):
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    return CollaborativeEngine(
        cfg, params, EngineConfig(cache=ccfg, max_batch=slots,
                                  capacity=capacity, **ecfg),
        key=jax.random.PRNGKey(3))


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9)))
            .astype(np.int32) for _ in range(n)]


def test_paged_tokens_bit_identical_to_dense(setup):
    """Acceptance: the same request fleet through the scheduler with
    dense per-slot KV and with the paged pool produces bit-identical
    tokens — paging moves memory layout, never logits — and the drained
    pool holds zero pages."""
    cfg, params = setup

    def run(paged):
        eng = _engine(cfg, params, slots=4, kv_paged=paged, page_size=8)
        sched = ContinuousBatchingScheduler(eng)
        for p in _prompts(cfg, 6, seed=5):
            sched.submit(p, max_new_tokens=6)
        return eng, sched.run()

    _, outs_d = run(False)
    eng_p, outs_p = run(True)
    assert sorted(outs_d) == sorted(outs_p)
    for rid in outs_d:
        np.testing.assert_array_equal(outs_d[rid], outs_p[rid])
    assert eng_p.kv_pool.pages_in_use == 0
    eng_p.kv_pool.check_invariants()
    assert eng_p.stats.kv_pages_in_use == 0


def test_prefix_sharing_across_admissions(setup):
    """Admissions whose prompts share a full-page prefix adopt the
    earlier request's pages: prefix_hits count, shared pages are not
    duplicated, and the sharing requests' tokens still match a cold solo
    run bitwise (sharing moves pages, never KV values)."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, 16)     # two full 8-pages
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 4)])
               .astype(np.int32) for _ in range(3)]

    eng = _engine(cfg, params, slots=3, kv_paged=True, page_size=8)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=5) for p in prompts]
    sched.step()                     # all three admitted concurrently
    s = eng.stats
    assert s.prefix_hits == 2        # second and third adopt the prefix
    # 3 requests x 3 pages dense-equivalent = 9; 2 shared prefix pages
    # counted once each: 9 - 2*2 = 5
    assert eng.kv_pool.pages_in_use == 5
    eng.kv_pool.check_invariants()
    outs = sched.run()

    solo_eng = _engine(cfg, params, slots=1, kv_paged=True, page_size=8)
    solo = ContinuousBatchingScheduler(solo_eng)
    r = solo.submit(prompts[2], max_new_tokens=5)
    np.testing.assert_array_equal(solo.run()[r.rid], outs[reqs[2].rid])


def test_page_backpressure_holds_fifo_head(setup):
    """A pool too small for the whole fleet admits what fits, stalls the
    FIFO head (admission_stalls counts the waiting ticks), and still
    drains every request to completion as retirements free pages."""
    cfg, params = setup
    # each request needs ceil((8+8)/8) = 2 pages; 3 fit, the 4th waits
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(4)]
    eng = _engine(cfg, params, slots=4, capacity=16, kv_paged=True,
                  page_size=8, kv_pages=6)
    sched = ContinuousBatchingScheduler(eng)
    reqs = [sched.submit(p, max_new_tokens=8) for p in prompts]
    sched.step()
    assert sched.num_active == 3           # page pool, not slots, is the gate
    assert eng.kv_pool.available == 0
    outs = sched.run()
    assert sorted(outs) == [r.rid for r in reqs]
    for r in reqs:
        assert len(outs[r.rid]) == 8       # nobody deadlocked mid-decode
    assert sched.stats.admission_stalls > 0
    assert eng.kv_pool.pages_in_use == 0


def test_fork_shares_pages_and_matches_parent_greedy(setup):
    """fork() clones a live greedy request copy-on-write: the child
    shares every page at fork time (one CoW page appears on the next
    append), and — decoding greedily from identical state — produces the
    parent's exact continuation."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=2, kv_paged=True, page_size=8)
    sched = ContinuousBatchingScheduler(eng)
    parent = sched.submit(_prompts(cfg, 1, seed=7)[0], max_new_tokens=8)
    sched.step()
    sched.step()                           # a few tokens in
    n_fork = len(parent.generated)
    child = sched.fork(parent.rid)
    assert len(child.generated) == n_fork  # born at the parent's progress
    outs = sched.run()
    np.testing.assert_array_equal(outs[parent.rid], outs[child.rid])
    assert eng.stats.cow_forks >= 1        # the shared partial page copied
    assert eng.kv_pool.pages_in_use == 0
    eng.kv_pool.check_invariants()


def test_fork_validation(setup):
    cfg, params = setup
    eng = _engine(cfg, params, slots=2, kv_paged=True, page_size=8)
    sched = ContinuousBatchingScheduler(eng)
    req = sched.submit(_prompts(cfg, 1, seed=8)[0], max_new_tokens=4)
    with pytest.raises(ValueError, match="not in a live slot"):
        sched.fork(req.rid)                # still queued
    sched.step()
    with pytest.raises(ValueError, match="born done"):
        sched.fork(req.rid, max_new_tokens=1)
    with pytest.raises(ValueError, match="capacity"):
        sched.fork(req.rid, max_new_tokens=500)
    # dense scheduler: fork is a paged-only operation
    eng_d = _engine(cfg, params, slots=2)
    sched_d = ContinuousBatchingScheduler(eng_d)
    rd = sched_d.submit(_prompts(cfg, 1, seed=8)[0], max_new_tokens=4)
    sched_d.step()
    with pytest.raises(RuntimeError, match="kv_paged"):
        sched_d.fork(rd.rid)


def test_dense_only_entry_points_guarded(setup):
    """The single-request dense conveniences must refuse loudly under
    kv_paged rather than silently bypass the pool."""
    cfg, params = setup
    eng = _engine(cfg, params, slots=2, kv_paged=True, page_size=8)
    prompt = _prompts(cfg, 1, seed=4)[0][None, :]
    for call in (lambda: eng.generate(prompt, steps=2),
                 lambda: eng.prefill(prompt),
                 lambda: eng.prefill_chunked(prompt),
                 lambda: eng.prefill_request(prompt)):
        with pytest.raises(RuntimeError, match="kv_paged"):
            call()
    # paged prefill requires the pool (init_slots) to exist first
    with pytest.raises(RuntimeError, match="init_slots"):
        eng.start_prefill(prompt)


def test_engine_config_validation(setup):
    cfg, params = setup
    ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=2, policy="lru")
    with pytest.raises(ValueError, match="page_size"):
        EngineConfig(cache=ccfg, kv_paged=True, capacity=64, page_size=0)
    with pytest.raises(ValueError, match="divisible by page_size"):
        EngineConfig(cache=ccfg, kv_paged=True, capacity=62, page_size=8)
    with pytest.raises(ValueError, match="kv_pages"):
        EngineConfig(cache=ccfg, kv_paged=True, capacity=64, page_size=8,
                     kv_pages=4)


def test_debug_invariants_env_checks_pool_each_tick(setup, monkeypatch):
    """REPRO_DEBUG_INVARIANTS=1 makes the scheduler run the pool's
    ref-count/free-list audit after every tick — the cheap way to catch a
    page-accounting regression at the step it happens instead of at drain.
    The flag is sampled at construction; without it the hook stays cold."""
    cfg, params = setup
    monkeypatch.setenv("REPRO_DEBUG_INVARIANTS", "1")
    eng = _engine(cfg, params, slots=2, kv_paged=True, page_size=8)
    sched = ContinuousBatchingScheduler(eng)
    assert sched._debug_invariants
    calls = []
    orig = eng.kv_pool.check_invariants
    monkeypatch.setattr(eng.kv_pool, "check_invariants",
                        lambda: (calls.append(1), orig())[-1])
    for p in _prompts(cfg, 3, seed=9):
        sched.submit(p, max_new_tokens=4)
    outs = sched.run()
    assert len(outs) == 3
    assert len(calls) >= 3            # at least one audit per decode tick
    assert eng.kv_pool.pages_in_use == 0

    monkeypatch.delenv("REPRO_DEBUG_INVARIANTS")
    cold = ContinuousBatchingScheduler(
        _engine(cfg, params, slots=2, kv_paged=True, page_size=8))
    assert not cold._debug_invariants
