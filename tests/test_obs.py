"""Observability tests: recorder mechanics (ring wraparound, span
nesting, no-op stand-in), histogram percentile parity against
``np.percentile``, Chrome trace export/validation, and the acceptance
invariant of the whole subsystem — serving with a live recorder produces
BIT-identical tokens to serving untraced, on both the dense and the
paged/segment-streamed paths, while covering every request's lifecycle
in the trace."""
import json

import jax
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.models import init_params
from repro.obs import (NULL_RECORDER, LogHistogram, NoopRecorder,
                       TraceRecorder, chrome_trace, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.export import lifecycle_coverage
from repro.obs.export import main as validate_main
from repro.obs.trace import now_ns
from repro.serving import build


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mixtral-8x7b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, recorder=None, **serving):
    serving.setdefault("capacity", 64)
    serving.setdefault("max_batch", 2)
    serving.setdefault("prefill_chunk", 4)
    _, sched = build(cfg, cache=dict(num_ways=4), serving=serving,
                     params=params, seed=0, recorder=recorder)
    rng = np.random.default_rng(7)
    for _ in range(3):
        sched.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(5, 9))),
                     max_new_tokens=5)
    outs = sched.run()
    return outs, sched.stats


# ---------------------------------------------------------------------------
# recorder mechanics
# ---------------------------------------------------------------------------

def test_ring_buffer_wraparound_keeps_newest():
    rec = TraceRecorder(capacity=8)
    for i in range(20):
        rec.instant("t", f"ev{i}", ts_ns=rec.t0_ns + i)
    assert len(rec) == 8
    assert rec.dropped == 12
    names = [ev.name for ev in rec.events()]
    assert names == [f"ev{i}" for i in range(12, 20)]     # oldest-first
    ts = [ev.ts_ns for ev in rec.events()]
    assert ts == sorted(ts)


def test_recorder_capacity_validation_and_iter():
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)
    rec = TraceRecorder(capacity=4)
    rec.counter("t", "gauge", 3.5)
    (ev,) = list(rec)
    assert ev.kind == "C" and ev.args == {"value": 3.5}


def test_span_nesting_orders_child_before_parent():
    rec = TraceRecorder(capacity=16)
    with rec.span("t", "outer"):
        with rec.span("t", "inner", args={"k": 1}):
            pass
    inner, outer = rec.events()         # exit order: inner completes first
    assert (inner.name, outer.name) == ("inner", "outer")
    assert inner.kind == outer.kind == "X"
    # child temporally nested within the parent
    assert outer.ts_ns <= inner.ts_ns
    assert inner.ts_ns + inner.dur_ns <= outer.ts_ns + outer.dur_ns


def test_retroactive_complete_clamps_negative_duration():
    rec = TraceRecorder(capacity=4)
    t = now_ns()
    rec.complete("t", "span", t, t - 100)     # clock misuse never negative
    assert rec.events()[0].dur_ns == 0


def test_noop_recorder_is_inert():
    rec = NoopRecorder()
    assert not rec.enabled and len(rec) == 0
    rec.complete("t", "a", 0, 1)
    rec.instant("t", "b")
    rec.counter("t", "c", 1.0)
    with rec.span("t", "d"):
        pass
    assert rec.events() == [] and list(rec) == []
    assert NULL_RECORDER.enabled is False
    assert TraceRecorder().enabled is True


# ---------------------------------------------------------------------------
# streaming log-bucket histogram
# ---------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=2.0, sigma=1.2, size=5000)
    h = LogHistogram()
    for s in samples:
        h.observe(float(s))
    assert h.count == len(samples)
    assert h.mean == pytest.approx(float(samples.mean()))
    assert h.min == pytest.approx(float(samples.min()))
    assert h.max == pytest.approx(float(samples.max()))
    for q in (50.0, 90.0, 95.0, 99.0):
        exact = float(np.percentile(samples, q))
        # geometric buckets grow 8% per step: interpolated estimates
        # land within one bucket of the exact rank statistic
        assert h.percentile(q) == pytest.approx(exact, rel=0.09), q


def test_histogram_edge_cases():
    h = LogHistogram()
    assert h.percentile(50.0) == 0.0 and h.mean == 0.0
    h.observe(0.0)                      # non-positive: own underflow bucket
    h.observe(5.0)
    assert h.count == 2
    assert h.percentile(0.0) == pytest.approx(h.min)
    assert h.percentile(100.0) == pytest.approx(h.max)
    with pytest.raises(ValueError):
        h.percentile(101.0)
    single = LogHistogram()
    single.observe(42.0)
    for q in (0.0, 50.0, 99.0):
        assert single.percentile(q) == pytest.approx(42.0)
    d = single.to_json()
    assert set(d) == {"count", "mean", "p50", "p95", "p99"}
    assert json.loads(json.dumps(d)) == d


def test_histogram_percentiles_ordered():
    rng = np.random.default_rng(1)
    h = LogHistogram()
    for s in rng.exponential(10.0, size=1000):
        h.observe(float(s) + 1e-6)
    p50, p95, p99 = h.percentiles()
    assert p50 <= p95 <= p99
    assert h.min <= p50 and p99 <= h.max


# ---------------------------------------------------------------------------
# traced serving: bit-identity + trace completeness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "paged_segment"])
def test_traced_serving_bit_identical_and_covered(setup, tmp_path, mode):
    cfg, params = setup
    serving = {} if mode == "dense" else dict(
        kv_paged=True, page_size=4, prefill_segment=4,
        admit_chunks_per_tick=1)
    base, _ = _serve(cfg, params, recorder=None, **serving)
    rec = TraceRecorder()
    traced, stats = _serve(cfg, params, recorder=rec, **serving)

    assert sorted(traced) == sorted(base)
    for rid in base:
        np.testing.assert_array_equal(traced[rid], base[rid])

    doc = chrome_trace(rec)
    assert validate_chrome_trace(doc) == []
    cover = lifecycle_coverage(doc)
    assert len(cover) == 3
    for track, spans in cover.items():
        assert {"queued", "prefill", "decode"} <= spans, (track, spans)

    # percentile channel populated on RunStats
    assert stats.ttft_ms_p50 > 0.0
    assert stats.tpot_ms_p50 > 0.0
    assert stats.ttft_ms_p50 <= stats.ttft_ms_p99

    # JSON artifact round-trips and passes the CLI validator
    path = tmp_path / "trace.json"
    write_chrome_trace(rec, str(path))
    assert json.loads(path.read_text()) == json.loads(json.dumps(doc))
    assert validate_main([str(path), "--require-lifecycle"]) == 0


def test_trace_orders_step_phases_within_tick(setup):
    cfg, params = setup
    rec = TraceRecorder()
    _serve(cfg, params, recorder=rec)
    by_track = {}
    for ev in rec.events():
        by_track.setdefault(ev.track, []).append(ev)
    ticks = [ev for ev in by_track["sched"] if ev.name == "tick"]
    assert ticks
    # every admission/decode+drain span nests inside some tick span
    for ev in by_track["sched"]:
        if ev.name in ("admission", "decode+drain"):
            assert any(t.ts_ns <= ev.ts_ns
                       and ev.ts_ns + ev.dur_ns <= t.ts_ns + t.dur_ns + 1
                       for t in ticks), ev.name
    # engine decode steps carry lane attribution counters at the drain
    eng = [ev for ev in by_track.get("engine", []) if ev.name == "decode_step"]
    assert eng and all(ev.kind == "X" for ev in eng)
    assert "lane:gpu" in by_track or "lane:cpu" in by_track


def test_trace_validator_flags_malformed_documents():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": "nope"}) != []
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 1, "tid": 1, "ts": 0},
        {"ph": "X", "name": "y", "pid": 1, "tid": 2, "ts": -5},
    ]}
    problems = validate_chrome_trace(bad)
    assert any("unknown phase" in p for p in problems)
    assert any("bad ts" in p for p in problems)
    assert any("no thread_name" in p for p in problems)
    # a complete span without dur, and a counter without value
    bad2 = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
         "args": {"name": "t"}},
        {"ph": "X", "name": "y", "pid": 1, "tid": 1, "ts": 0},
        {"ph": "C", "name": "g", "pid": 1, "tid": 1, "ts": 0, "args": {}},
    ]}
    problems = validate_chrome_trace(bad2)
    assert any("without non-negative dur" in p for p in problems)
    assert any("without args.value" in p for p in problems)


def test_cancelled_request_gets_terminal_instant(setup):
    cfg, params = setup
    rec = TraceRecorder()
    _, sched = build(cfg, cache=dict(num_ways=4),
                     serving=dict(capacity=64, max_batch=1,
                                  prefill_chunk=4),
                     params=params, seed=0, recorder=rec)
    rng = np.random.default_rng(3)
    keep = sched.submit(rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=4)
    gone = sched.submit(rng.integers(0, cfg.vocab_size, 6),
                        max_new_tokens=4)
    assert sched.cancel(gone.rid)
    sched.run()
    doc = chrome_trace(rec)
    assert validate_chrome_trace(doc) == []
    names_by_tid = {ev["tid"]: ev["args"]["name"]
                    for ev in doc["traceEvents"]
                    if ev.get("ph") == "M"}
    instants = {(names_by_tid[ev["tid"]], ev["name"])
                for ev in doc["traceEvents"] if ev.get("ph") == "i"}
    assert (f"req:{gone.rid}", "cancelled") in instants
    assert (f"req:{keep.rid}", "done") in instants
    # cancelled-in-queue lifecycles cover queued only; finished cover all
    cover = lifecycle_coverage(doc)
    assert "queued" in cover[f"req:{gone.rid}"]
    assert "decode" not in cover[f"req:{gone.rid}"]
    assert {"queued", "prefill", "decode"} <= cover[f"req:{keep.rid}"]
