"""Mamba2 SSD: chunked dual form vs sequential recurrence; decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, ssd_decode_step


def _seq_ref(x, dt, A_log, B, C):
    """Direct O(S) recurrence in float64-ish numpy."""
    Bb, S, nh, hp = x.shape
    ds = B.shape[-1]
    h = np.zeros((Bb, nh, ds, hp), np.float64)
    ys = np.zeros((Bb, S, nh, hp), np.float64)
    a = -np.exp(np.asarray(A_log, np.float64)) * np.asarray(dt, np.float64)
    xd = np.asarray(x, np.float64) * np.asarray(dt, np.float64)[..., None]
    Bn, Cn = np.asarray(B, np.float64), np.asarray(C, np.float64)
    for t in range(S):
        h = np.exp(a[:, t])[..., None, None] * h + \
            np.einsum("bn,bhp->bhnp", Bn[:, t], xd[:, t])
        ys[:, t] = np.einsum("bn,bhnp->bhp", Cn[:, t], h)
    return ys, h


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 96)])
@pytest.mark.parametrize("seed", [0, 1])
def test_chunked_matches_sequential(S, chunk, seed):
    Bb, nh, hp, ds = 2, 4, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (Bb, S, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, S, nh)))
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (Bb, S, ds)) * 0.5
    C = jax.random.normal(ks[4], (Bb, S, ds)) * 0.5
    y, h = ssd_chunked(x, dt, A_log, B, C, chunk=chunk)
    y_ref, h_ref = _seq_ref(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_decode_continues_chunked_state():
    """prefill (chunked) then decode steps == one long chunked pass."""
    Bb, S, nh, hp, ds, extra = 1, 64, 2, 4, 8, 5
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    T = S + extra
    x = jax.random.normal(ks[0], (Bb, T, nh, hp), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, T, nh)))
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (Bb, T, ds)) * 0.5
    C = jax.random.normal(ks[4], (Bb, T, ds)) * 0.5

    y_all, _ = ssd_chunked(x, dt, A_log, B, C, chunk=16)
    y_pre, h = ssd_chunked(x[:, :S], dt[:, :S], A_log, B[:, :S], C[:, :S],
                           chunk=16)
    ys = [np.asarray(y_pre)]
    for t in range(S, T):
        y_t, h = ssd_decode_step(x[:, t], dt[:, t], A_log, B[:, t], C[:, t], h)
        ys.append(np.asarray(y_t)[:, None])
    got = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(got, np.asarray(y_all), rtol=2e-4, atol=2e-4)


def test_state_decay_property():
    """With strongly negative A (fast decay), output ~= local D-free term:
    far-past inputs must not influence current output."""
    Bb, S, nh, hp, ds = 1, 32, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (Bb, S, nh, hp), jnp.float32)
    dt = jnp.full((Bb, S, nh), 50.0)        # huge dt -> exp(-50)*state ~ 0
    A_log = jnp.zeros((nh,))
    B = jax.random.normal(ks[3], (Bb, S, ds))
    C = jax.random.normal(ks[4], (Bb, S, ds))
    y, _ = ssd_chunked(x, dt, A_log, B, C, chunk=8)
    # memoryless reference: h_t = B_t (x_t dt_t)
    xd = x * dt[..., None]
    y_ref = jnp.einsum("bsn,bsn,bshp->bshp",
                       C, B, xd)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
