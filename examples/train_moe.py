"""Train a ~100M-param MoE LM for a few hundred steps on the full stack:
sort-based dispatch MoE, AdamW+ZeRO path, remat, async checkpointing.

    PYTHONPATH=src python examples/train_moe.py [--steps 300]

A ~100M config of the qwen3-moe family (16 experts, top-2). Loss should
drop well below the uniform baseline ln(vocab)≈8.0 within a few hundred
steps; MoE aux loss stays near 1.0 (balanced routing).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import MoEConfig, OptimizerConfig, ShapeConfig, get_config
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    args = ap.parse_args()

    base = get_config("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(
        base, num_layers=6, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, vocab_size=4096, max_seq_len=args.seq,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=512))
    print(f"[train_moe] params={cfg.param_count()/1e6:.1f}M "
          f"active={cfg.param_count(active_only=True)/1e6:.1f}M")

    shape = ShapeConfig("ex", args.seq, args.batch, "train")
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    data = SyntheticLM(cfg, shape)
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    mgr = CheckpointManager("/tmp/repro_moe_ckpt", keep=2)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss={float(m['loss']):.4f} "
                  f"xent={float(m['xent']):.4f} aux={float(m['aux']):.3f}",
                  flush=True)
        if (i + 1) % 100 == 0:
            mgr.save(i + 1, {"params": params, "opt": opt})
    mgr.wait()
    dt = time.time() - t0
    print(f"[train_moe] {args.steps} steps in {dt:.0f}s "
          f"({args.steps*args.batch*args.seq/dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
