"""Paper workflow end-to-end: cache-policy and geometry sweep on a live
(reduced) Phi-3.5-MoE model, mirroring the shape of paper Fig. 5/6 — now
served through the continuous-batching scheduler via the ``build()``
façade: 4 request slots share one expert cache, requests admit and retire
without draining the batch, and prompts warm the cache through the
chunked-prefill pipeline.

    PYTHONPATH=src python examples/serve_collaborative.py
"""
import time

import jax
import numpy as np

from repro.config import get_config, reduced
from repro.models import init_params
from repro.serving import build

SLOTS = 4
REQUESTS = 6
NEW_TOKENS = 16


def main():
    cfg = reduced(get_config("phi35-moe"))
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)

    E = cfg.moe.num_experts
    print(f"model: {cfg.name} (reduced) layers={cfg.num_layers} experts={E} "
          f"slots={SLOTS} requests={REQUESTS}")
    print(f"{'config':>14s} {'policy':>7s} {'pf':>3s} {'hit rate':>9s} "
          f"{'pf hits':>8s} {'pred acc':>8s} {'tok/s':>7s}")
    for ways in (2, 4):
        for policy in ("lru", "fifo", "random"):
            for prefetch in ((False, True) if policy == "lru"
                             else (False,)):
                _, sched = build(
                    cfg, cache=dict(num_ways=ways, policy=policy),
                    serving=dict(max_batch=SLOTS, capacity=128,
                                 prefetch=prefetch),
                    seed=1, params=params)
                for r in range(REQUESTS):
                    plen = int(rng.integers(8, 17))
                    sched.submit(rng.integers(0, cfg.vocab_size, plen),
                                 max_new_tokens=NEW_TOKENS)
                t0 = time.time()
                outs = sched.run()
                dt = time.time() - t0
                stats = sched.stats
                total = sum(len(o) for o in outs.values())
                print(f"  (N={cfg.num_layers:2d},M={ways}) {policy:>7s} "
                      f"{'on' if prefetch else 'off':>3s} "
                      f"{stats.hit_rate:9.3f} "
                      f"{stats.prefetch_hits:8d} "
                      f"{stats.prediction_accuracy:8.3f} {total/dt:7.1f}")
    print("(wall tok/s on this CPU container is not the paper metric — the "
          "calibrated benchmark is benchmarks/fig5_throughput.py; pf=on "
          "rows add the cross-layer speculative expert prefetch)")

    # overlapped admission demo: a long-prompt newcomer warms one chunk
    # per tick in the PREFILLING phase while the established requests
    # keep decoding (synchronous admission would stall them for the whole
    # replay — measured in benchmarks/admission_overlap.py)
    _, sched = build(cfg, cache=dict(num_ways=2),
                     serving=dict(max_batch=2, capacity=128,
                                  prefill_chunk=8,
                                  admit_chunks_per_tick=1),
                     seed=1, params=params, max_queue=4)
    est = sched.submit(rng.integers(0, cfg.vocab_size, 8),
                       max_new_tokens=24)
    sched.step()
    newcomer = sched.submit(rng.integers(0, cfg.vocab_size, 64),
                            max_new_tokens=8)
    warm_ticks = 0
    sched.step()                       # admission tick: PREFILLING begins
    while sched.prefill_pending:
        sched.step()
        warm_ticks += 1
    est_during = len(est.generated)
    sched.run()
    print(f"overlapped admission: 64-token prompt warmed over "
          f"{warm_ticks} ticks while the established request decoded "
          f"{est_during - 1} tokens alongside "
          f"(newcomer streamed {len(newcomer.generated)} tokens after)")


if __name__ == "__main__":
    main()
