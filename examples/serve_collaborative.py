"""Paper workflow end-to-end: cache-policy and geometry sweep on a live
(reduced) Phi-3.5-MoE model, mirroring the shape of paper Fig. 5/6.

    PYTHONPATH=src python examples/serve_collaborative.py
"""
import time

import jax
import numpy as np

from repro.config import CacheConfig, get_config, reduced
from repro.models import init_params
from repro.serving import CollaborativeEngine, EngineConfig


def main():
    key = jax.random.PRNGKey(1)
    cfg = reduced(get_config("phi35-moe"))
    params = init_params(cfg, key)
    prompt = np.asarray(jax.random.randint(key, (1, 16), 0, cfg.vocab_size))

    E = cfg.moe.num_experts
    print(f"model: {cfg.name} (reduced) layers={cfg.num_layers} experts={E}")
    print(f"{'config':>14s} {'policy':>7s} {'hit rate':>9s} {'tok/s':>7s}")
    for ways in (2, 4):
        for policy in ("lru", "fifo", "random"):
            ccfg = CacheConfig(num_indexes=cfg.num_layers, num_ways=ways,
                               policy=policy)
            eng = CollaborativeEngine(
                cfg, params, EngineConfig(cache=ccfg, capacity=128), key=key)
            t0 = time.time()
            _, stats = eng.generate(prompt, steps=32)
            dt = time.time() - t0
            print(f"  (N={cfg.num_layers:2d},M={ways}) {policy:>7s} "
                  f"{stats['hit_rate']:9.3f} {32/dt:7.1f}")
    print("(wall tok/s on this CPU container is not the paper metric — the "
          "calibrated benchmark is benchmarks/fig5_throughput.py)")


if __name__ == "__main__":
    main()
