"""Quickstart: the paper's expert cache in 60 lines.

Builds a reduced Mixtral-8x7B, serves it through the two-tier
collaborative engine, and prints the cache behaviour the paper is about.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.config import CacheConfig
from repro.core import NumpyCache, TraceConfig, synthetic_trace, trace_stats
from repro.serving import build


def main():
    key = jax.random.PRNGKey(0)

    # 1. The cache itself, replaying a router trace calibrated to the
    # paper's Fig. 2 statistics (consecutive-token expert reuse).
    trace = synthetic_trace(TraceConfig(num_tokens=500, num_layers=32,
                                        num_experts=8))
    print("trace stats vs paper Fig.2:", trace_stats(trace))
    for policy in ("lru", "fifo", "random"):
        c = NumpyCache(CacheConfig(num_indexes=14, num_ways=4,
                                   policy=policy), num_experts=8)
        for t in range(trace.shape[0]):
            for l in range(trace.shape[1]):
                c.access(l, trace[t, l])
        print(f"  (14,4) {policy:6s} hit rate = {c.hit_rate:.3f}")

    # 2. End-to-end: a reduced Mixtral served with the cache + CPU tier,
    # via the one-call serving façade.
    eng, _ = build("mixtral-8x7b", cache=dict(num_ways=2),
                   serving=dict(capacity=128))
    prompt = np.asarray(jax.random.randint(key, (1, 16), 0,
                                           eng.cfg.vocab_size))
    out, stats = eng.generate(prompt, steps=24)
    print(f"generated {out.shape[1]} tokens; "
          f"cache hit rate {stats.hit_rate:.3f}, "
          f"{stats.fetched_experts} post-fetches, "
          f"{stats.host_assignments} host-tier expert runs")

    # 3. Cross-layer speculative prefetch: layer l+1's router runs on
    # layer l's output and the predicted experts are reserved + streamed
    # one layer early. Same tokens, higher demand hit rate.
    eng_pf, _ = build("mixtral-8x7b", cache=dict(num_ways=2),
                      serving=dict(capacity=128, prefetch=True),
                      params=eng.params)          # same weights: bit-exact
    out_pf, stats_pf = eng_pf.generate(prompt, steps=24)
    assert (out_pf == out).all(), "prefetch must never change tokens"
    print(f"with speculative prefetch: hit rate {stats_pf.hit_rate:.3f} "
          f"(was {stats.hit_rate:.3f}), prediction accuracy "
          f"{stats_pf.prediction_accuracy:.3f}, "
          f"{stats_pf.prefetch_wasted} wasted fetches "
          f"— identical tokens")


if __name__ == "__main__":
    main()
